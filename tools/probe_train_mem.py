import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Bisect train_4k memory: forward only vs grad vs full step."""

import sys

import jax
import jax.numpy as jnp

sys.path.insert(0, "/root/repo/src")

from repro.configs import get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import param_specs, train_batch_specs
from repro.configs.base import INPUT_SHAPES
from repro.models import model as M
from repro.sharding.policy import make_policy

arch = sys.argv[1] if len(sys.argv) > 1 else "internvl2_76b"
mode = sys.argv[2] if len(sys.argv) > 2 else "fwd"

cfg = get_config(arch)
shape = INPUT_SHAPES["train_4k"]
mesh = make_production_mesh()
policy = make_policy(mesh, cfg)
p_shapes = param_specs(cfg)
p_shard = policy.params_shardings(p_shapes)
batch = train_batch_specs(cfg, shape)
accum = cfg.grad_accum


def micro(b):
    return jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct((a.shape[0] // accum, *a.shape[1:]), a.dtype), b
    )


mb = micro(batch)

if mode == "fwd":
    fn = lambda p, b: M.forward_train(p, b, cfg)["loss"]
elif mode == "fwd_noremat":
    import dataclasses
    cfg2 = dataclasses.replace(cfg, remat=False)
    fn = lambda p, b: M.forward_train(p, b, cfg2)["loss"]
elif mode == "grad":
    fn = lambda p, b: jax.grad(lambda pp: M.forward_train(pp, b, cfg)["loss"])(p)
elif mode == "grad_nobranch":
    import dataclasses
    cfg2 = dataclasses.replace(cfg, branch_layers=(), use_mtp=False)
    fn = lambda p, b: jax.grad(lambda pp: M.forward_train(pp, b, cfg2)["loss"])(p)
else:
    raise SystemExit(f"unknown mode {mode}")

from repro.sharding.ctx import activation_sharding
with mesh, activation_sharding(mesh, ("data",)):
    lowered = jax.jit(
        fn, in_shardings=(p_shard, policy.data_shardings(mb))
    ).lower(p_shapes, mb)
    c = lowered.compile()
    ma = c.memory_analysis()
    print(
        f"{arch} {mode}: arg={ma.argument_size_in_bytes/1e9:.2f} "
        f"out={ma.output_size_in_bytes/1e9:.2f} temp={ma.temp_size_in_bytes/1e9:.2f} GB"
    )
