#!/bin/sh
# Tier-1 CI entry: run the test suite exactly as ROADMAP.md specifies
# (tests/test_compaction.py, tests/test_kernel_runtime.py,
# tests/test_scheduler.py and the runtime/controller suites are part of
# the default collection), then smoke-run the serving benchmark sweep
# and the kernel-vs-jnp decode sweep in fast mode so the
# masked-vs-compacted FLOPs assertion, the 1-sync invariant, the
# serial-vs-pipelined overlap cell, the continuous-vs-lock-step request
# cell (Poisson arrivals, recycled KV slots — REPRO_BENCH_FAST runs it;
# `make bench-requests` selects it alone), every Pallas kernel path
# (interpret mode off-TPU, identical-trajectory assert inline), and the
# batched-exit-heads cells (multi-head kernel bitwise vs single-head,
# plus the heads/probe_step_k5 batched-vs-sequential decode step with
# its bitwise-trajectory assert) are exercised end to end on every CI
# pass.  bench_check also appends each bundle's metrics to the
# BENCH_history.jsonl per-PR trend series.
# A second pytest process then runs the multi-device lane: XLA_FLAGS
# must create the 8 virtual CPU devices *before jax initializes*, so the
# sharded-tier equivalence tests (tests/test_sharded_tiers.py — SPMD
# trajectory identity, 1-sync invariant, policy lowering across all
# configs) cannot share the first process.  The lane runs the *whole*
# suite under the 8-device mesh — the existing tier-1 tests double as a
# does-everything-still-hold-with-devices-visible check (they pass
# unchanged; only mesh-marked tests actually shard anything).
# Usage: tools/ci.sh [extra pytest args]
#   REPRO_CI_BENCH=0 skips the benchmark smokes (pytest only).
#   REPRO_CI_SHARDED=0 skips the multi-device lane;
#   REPRO_CI_SHARDED=fast restricts it to tests/test_sharded_tiers.py.
set -e
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
if [ "${REPRO_CI_SHARDED:-1}" != "0" ]; then
    if [ "${REPRO_CI_SHARDED:-1}" = "fast" ]; then
        sharded_targets="tests/test_sharded_tiers.py"
    else
        sharded_targets=""
    fi
    XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
        PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python -m pytest -x -q $sharded_targets
fi
if [ "${REPRO_CI_BENCH:-1}" != "0" ]; then
    REPRO_BENCH_FAST=1 PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python benchmarks/serving_step.py
    REPRO_BENCH_FAST=1 PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python benchmarks/kernel_micro.py
    python tools/bench_check.py BENCH_serving.json BENCH_kernels.json
fi
