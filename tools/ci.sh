#!/bin/sh
# Tier-1 CI entry: run the test suite exactly as ROADMAP.md specifies
# (tests/test_compaction.py, tests/test_kernel_runtime.py,
# tests/test_scheduler.py and the runtime/controller suites are part of
# the default collection), then smoke-run the serving benchmark sweep
# and the kernel-vs-jnp decode sweep in fast mode so the
# masked-vs-compacted FLOPs assertion, the 1-sync invariant, the
# serial-vs-pipelined overlap cell, the continuous-vs-lock-step request
# cell (Poisson arrivals, recycled KV slots — REPRO_BENCH_FAST runs it;
# `make bench-requests` selects it alone), and every Pallas kernel path
# (interpret mode off-TPU, identical-trajectory assert inline) are
# exercised end to end on every CI pass.
# Usage: tools/ci.sh [extra pytest args]
#   REPRO_CI_BENCH=0 skips the benchmark smokes (pytest only).
set -e
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
if [ "${REPRO_CI_BENCH:-1}" != "0" ]; then
    REPRO_BENCH_FAST=1 PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python benchmarks/serving_step.py
    REPRO_BENCH_FAST=1 PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python benchmarks/kernel_micro.py
fi
