#!/bin/sh
# Tier-1 CI entry: run the test suite exactly as ROADMAP.md specifies.
# Usage: tools/ci.sh [extra pytest args]
set -e
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
