#!/usr/bin/env python
"""Benchmark regression check: diff a BENCH_*.json bundle against the
last committed one.

Usage::

    python tools/bench_check.py BENCH_serving.json [BENCH_kernels.json ...]

For each bundle, the baseline is ``git show <ref>:<file>`` (ref from
``REPRO_BENCH_REF``, default HEAD).  Per cell:

  * cells whose ``config`` differs from the baseline's are skipped (a
    fast-mode run is never diffed against a full-mode baseline);
  * ``strict`` metrics must match exactly — these are structure-derived
    (host syncs/step, decode-step counts, analytic FLOPs, solver cuts)
    and only change when the code changes;
  * ``timing`` metrics are wall-clock: a value more than
    ``REPRO_BENCH_TOL``x the baseline (default 3.0 — CI hosts are noisy)
    is flagged as a regression.  Faster is never flagged.

Exit status: 0 = clean (including "no committed baseline yet" — the
first run seeds the trajectory); 1 = strict mismatch or timing
regression.

Besides the pass/fail diff, every run appends each bundle's metrics to
``BENCH_history.jsonl`` (override with ``REPRO_BENCH_HISTORY``; empty
disables) — an append-only per-PR trend series: one JSON line per
(bench, git_sha) with the flattened strict+timing metrics of every cell.
Committing the file alongside the bundles gives the repo a queryable
perf trajectory across PRs (e.g. the ``heads/probe_step_k5`` speedup
over time) instead of only the latest snapshot.  A run whose metrics are
identical to the last recorded entry for that bench is not re-appended.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

TOL = float(os.environ.get("REPRO_BENCH_TOL", "3.0"))
REF = os.environ.get("REPRO_BENCH_REF", "HEAD")
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
HISTORY = os.environ.get(
    "REPRO_BENCH_HISTORY", os.path.join(REPO_ROOT, "BENCH_history.jsonl")
)


def committed(relpath: str) -> dict | None:
    out = subprocess.run(
        ["git", "show", f"{REF}:{relpath}"],
        capture_output=True, text=True, cwd=REPO_ROOT,
    )
    if out.returncode != 0:
        return None
    try:
        return json.loads(out.stdout)
    except json.JSONDecodeError:
        return None


def append_history(cur: dict) -> None:
    """Append this bundle's metrics to the append-only trend series.

    One line per run: ``{"bench", "git_sha", "cells": {name: metrics}}``
    with each cell's strict and timing metrics flattened together.  The
    series is per-PR, not per-invocation: a run identical to the last
    recorded entry for the same bench (re-running the checker in one
    working tree) is skipped, so the file only grows when the numbers or
    the commit change.
    """
    if not HISTORY:
        return
    entry = {
        "bench": cur.get("bench"),
        "git_sha": cur.get("git_sha"),
        "cells": {
            name: {**cell.get("strict", {}), **cell.get("timing", {})}
            for name, cell in cur.get("cells", {}).items()
        },
    }
    last = None
    if os.path.exists(HISTORY):
        with open(HISTORY) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if rec.get("bench") == entry["bench"]:
                    last = rec
    if last is not None and all(
        last.get(k) == entry[k] for k in ("bench", "git_sha", "cells")
    ):
        return
    with open(HISTORY, "a") as f:
        f.write(json.dumps(entry, sort_keys=True) + "\n")


def check_bundle(path: str) -> list[str]:
    """Returns a list of human-readable problems (empty = clean)."""
    rel = os.path.relpath(os.path.abspath(path), REPO_ROOT)
    with open(path) as f:
        cur = json.load(f)
    append_history(cur)
    base = committed(rel)
    if base is None:
        print(f"{rel}: no committed baseline at {REF} — seeding trajectory")
        return []
    problems: list[str] = []
    compared = skipped = 0
    for name, cell in cur.get("cells", {}).items():
        ref_cell = base.get("cells", {}).get(name)
        if ref_cell is None:
            continue  # new cell: nothing to diff against
        if cell.get("config") != ref_cell.get("config"):
            skipped += 1
            continue
        compared += 1
        for key, want in ref_cell.get("strict", {}).items():
            got = cell.get("strict", {}).get(key)
            if got != want:
                problems.append(
                    f"{rel}:{name}: strict metric {key!r} changed: "
                    f"{want!r} -> {got!r}"
                )
        for key, want in ref_cell.get("timing", {}).items():
            got = cell.get("timing", {}).get(key)
            if not isinstance(got, (int, float)) or not isinstance(
                want, (int, float)
            ):
                continue
            if want > 0 and got > want * TOL:
                problems.append(
                    f"{rel}:{name}: timing {key!r} regressed "
                    f"{got / want:.2f}x (tol {TOL}x): {want:.3f} -> {got:.3f}"
                )
    print(f"{rel}: {compared} cells compared vs {base.get('git_sha', '?')[:12]}"
          f", {skipped} skipped (config changed), {len(problems)} problems")
    return problems


def main(argv: list[str]) -> int:
    paths = argv or ["BENCH_serving.json", "BENCH_kernels.json"]
    problems: list[str] = []
    for p in paths:
        if not os.path.exists(p):
            print(f"{p}: not found (benchmark did not emit a bundle?)")
            problems.append(f"{p}: missing bundle")
            continue
        problems += check_bundle(p)
    for msg in problems:
        print(f"REGRESSION: {msg}", file=sys.stderr)
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
