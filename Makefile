PY := python

.PHONY: test test-fast test-sharded bench-serving bench-serving-fast bench-overlap bench-requests bench-faults bench-kernels bench-kernels-full bench-check example

# Tier-1 verify (ROADMAP): the full suite with the src layout on the path.
test:
	PYTHONPATH=src $(PY) -m pytest -x -q

test-fast: test-sharded
	PYTHONPATH=src $(PY) -m pytest -x -q tests/test_tiers.py tests/test_compaction.py tests/test_scheduler.py tests/test_multitier.py tests/test_hlo_analysis.py

# Multi-device lane: 8 virtual CPU devices (XLA_FLAGS must precede jax
# init, hence the separate pytest process) running the sharded-tier
# equivalence suite (SPMD trajectory identity, policy lowering, mesh
# construction, sharding-aware partition costs).
test-sharded:
	XLA_FLAGS="--xla_force_host_platform_device_count=8" PYTHONPATH=src $(PY) -m pytest -x -q tests/test_sharded_tiers.py

bench-serving:
	PYTHONPATH=src $(PY) benchmarks/serving_step.py

# CI smoke: one batch/split/regime cell, short step counts.
bench-serving-fast:
	REPRO_BENCH_FAST=1 PYTHONPATH=src $(PY) benchmarks/serving_step.py

# Serial-vs-pipelined overlap cell only: asserts pipelined steady-state
# step time <= serial under simulate_network=True and the plan flip.
bench-overlap:
	REPRO_BENCH_FAST=1 REPRO_BENCH_ONLY=overlap PYTHONPATH=src $(PY) benchmarks/serving_step.py

# Continuous-vs-lock-step request cell only: Poisson arrivals, mixed
# prompt lengths/budgets with early exits; asserts continuous admission
# beats gang (lock-step) tokens/sec at one host sync per decode step.
bench-requests:
	REPRO_BENCH_FAST=1 REPRO_BENCH_ONLY=requests PYTHONPATH=src $(PY) benchmarks/serving_step.py

# Fault-plane cell only: scripted mid-run link flap on a K=3 stack ->
# retries, breaker open, degraded tokens from the fallback head, and an
# availability re-solve that moves the cut off the sick hop.  Asserts
# every request completes with no leaked KV slots.
bench-faults:
	REPRO_BENCH_FAST=1 REPRO_BENCH_ONLY=faults PYTHONPATH=src $(PY) benchmarks/serving_step.py

# Kernel-vs-jnp decode hot path sweep (flash_decode / fused exit decision /
# ssd_update / end-to-end TierExecutor step) in CI smoke mode: tiny shapes,
# kernels in interpret mode off-TPU, trajectory + 1-sync asserts inline.
bench-kernels:
	REPRO_BENCH_FAST=1 PYTHONPATH=src $(PY) benchmarks/kernel_micro.py

# Full sweep incl. the serving-scale jnp reference timings.
bench-kernels-full:
	PYTHONPATH=src $(PY) benchmarks/kernel_micro.py

# Diff the emitted BENCH_*.json bundles against the last committed ones:
# strict (structural) metrics exactly, wall-clock within REPRO_BENCH_TOL.
bench-check:
	$(PY) tools/bench_check.py BENCH_serving.json BENCH_kernels.json

example:
	PYTHONPATH=src $(PY) examples/serve_partitioned.py
