"""Unified K-tier execution runtime for BranchyNet serving.

One decode step crosses up to K tiers (device -> edge -> ... -> cloud).
Every tier runs a contiguous trunk segment, evaluates the side branches
that live strictly inside it, and ships survivors across its uplink.  The
monolithic :class:`~repro.serving.engine.ServingEngine` (K=1), the paper's
:class:`~repro.serving.partitioned.PartitionedServer` (K=2) and the
beyond-paper :class:`~repro.serving.multitier.MultiTierServer` (K>=3) are
all thin configurations of the same :class:`TierExecutor`.

Branch placement follows the paper's semantics (Sec. IV-B, Fig. 2(c)):

  * a branch sitting exactly at a cut is discarded — the residual stream
    ships immediately;
  * the final tier evaluates no side branches (the cloud classifies at the
    output layer), except in the single-tier case where the whole
    BranchyNet runs in one place.

Exit masking is device-resident: branch entropy thresholding, token
selection, and survivor accounting are fused in jnp inside each tier's
jitted segment, and the step performs exactly ONE device->host sync — a
single ``jax.device_get`` of the packed (tokens, exit masks, entropies)
pytree.  The old per-branch ``np.asarray``/``int(...)`` round trips inside
the decode loop are gone; ``TierExecutor.host_syncs`` counts the remaining
fetches so benchmarks/tests can assert the invariant.

Segment functions are cached by their spec ``(layer_lo, layer_hi,
branches, head)``: a repartition that moves one cut re-uses the jitted
(and XLA-compiled) callables of every unchanged tier segment.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.calibration import normalized_entropy
from repro.models.layers import norm_apply
from repro.models.model import (
    _branch_logits,
    _unembed,
    embed_decode,
    run_trunk,
    trunk_layout,
)

__all__ = [
    "TierSegment",
    "TierStepResult",
    "TierExecutor",
    "segments_for_cuts",
    "bytes_per_sequence",
    "TOKEN_ID_BYTES",
]

#: Per-sequence payload of a hop taken before any trunk layer ran: the raw
#: token id (the prompt itself crossed at prefill time).
TOKEN_ID_BYTES = 4.0


@dataclasses.dataclass(frozen=True)
class TierSegment:
    """One tier's share of the trunk: layers ``[layer_lo, layer_hi)``
    (absolute, 0-based), the 1-based branch collect points it evaluates,
    and the uplink to the next tier (bits/s; ``None`` on the last tier)."""

    name: str
    layer_lo: int
    layer_hi: int
    branches: tuple[int, ...] = ()
    uplink_bps: float | None = None

    @property
    def is_empty(self) -> bool:
        return self.layer_hi == self.layer_lo

    def spec(self, head: bool) -> tuple:
        """Cache key for the compiled segment function."""
        return (self.layer_lo, self.layer_hi, self.branches, head)


def bytes_per_sequence(cfg: ModelConfig, cut_layer: int) -> float:
    """Payload one surviving sequence ships at a cut after ``cut_layer``
    (1-based; 0 = before any trunk layer -> raw token id)."""
    if cut_layer == 0:
        return TOKEN_ID_BYTES
    return cfg.d_model * 2.0  # bf16 residual stream


def segments_for_cuts(
    cfg: ModelConfig,
    cuts: Sequence[int],
    *,
    names: Sequence[str] | None = None,
    uplinks: Sequence[float] | None = None,
) -> tuple[TierSegment, ...]:
    """Generic plan -> runtime adapter: monotone 1-based cut points
    ``(c_1 .. c_{K-1})`` become K :class:`TierSegment` specs.

    Tier j runs layers ``(c_j, c_{j+1}]`` (1-based).  Branch placement per
    the module docstring: strictly inside a tier, never on the final tier
    of a K>=2 stack, and a branch at a cut is discarded.
    """
    total = sum(n for _, _, n in trunk_layout(cfg))
    bounds = (0, *(int(c) for c in cuts), total)
    if any(b > a for a, b in zip(bounds[1:], bounds[:-1])):
        raise ValueError(f"cuts must be non-decreasing in [0, {total}]: {cuts}")
    k = len(bounds) - 1
    segs = []
    for j in range(k):
        lo, hi = bounds[j], bounds[j + 1]
        if j == k - 1 and k > 1:
            brs: tuple[int, ...] = ()  # the cloud evaluates no branches
        else:
            # Strict at the cut (branch there is discarded); at the trunk
            # end there is no cut, so the deepest branch is evaluated.
            brs = tuple(
                b for b in cfg.branch_layers
                if lo < b and (b <= hi if hi == total else b < hi)
            )
        name = names[j] if names else f"tier{j}"
        up = uplinks[j] if uplinks and j < len(uplinks) else None
        segs.append(TierSegment(name, lo, hi, brs, up if j < k - 1 else None))
    return tuple(segs)


@dataclasses.dataclass
class TierStepResult:
    """Everything a server needs from one decode step, fetched in one
    device->host sync (except the device-resident feedback arrays)."""

    tokens: np.ndarray  # (B,) chosen token per sequence
    exited: np.ndarray  # (B,) bool — exited at some side branch
    exit_tier: np.ndarray  # (B,) int32 tier index of the exit, -1 = main head
    branch_take: dict[int, np.ndarray]  # layer -> (B,) bool first-exit mask
    branch_entropy: dict[int, np.ndarray]  # layer -> (B,) normalized entropy
    shipped_per_hop: tuple[int, ...]  # survivors crossing each executed hop
    bytes_per_hop: tuple[float, ...]
    tokens_dev: jax.Array  # device copy for the next step's input
    last_logits: jax.Array  # (B, V) main-head logits, device-resident


class TierExecutor:
    """Compiles one jitted segment per tier and runs the K-hop decode step.

    ``install`` swaps the segment list in place; segment functions are
    cached by spec so an unchanged tier is never re-jitted.
    """

    def __init__(
        self, cfg: ModelConfig, params: Any, segments: Sequence[TierSegment]
    ):
        self.cfg = cfg
        self.params = params
        self.total_layers = sum(n for _, _, n in trunk_layout(cfg))
        self._fn_cache: dict[tuple, Any] = {}
        self.host_syncs = 0
        self.install(segments)

    # -------------------------------------------------------------- plan
    def install(self, segments: Sequence[TierSegment]) -> None:
        """Install a new tier plan, re-using compiled unchanged segments."""
        segments = tuple(segments)
        if not segments or segments[0].layer_lo != 0:
            raise ValueError("first segment must start at layer 0")
        if segments[-1].layer_hi != self.total_layers:
            raise ValueError("last segment must end at the trunk tail")
        for a, b in zip(segments, segments[1:]):
            if a.layer_hi != b.layer_lo:
                raise ValueError("segments must tile the trunk contiguously")
        self.segments = segments
        # The final head runs on the last tier that runs any layers.
        self._head_idx = max(
            i for i, s in enumerate(segments) if not s.is_empty
        )
        self._fns = [
            self._segment_fn(seg, head=(i == self._head_idx))
            if not seg.is_empty else None
            for i, seg in enumerate(segments)
        ]

    def segment_fn(self, index: int):
        """The compiled callable for segment ``index`` (None if empty)."""
        return self._fns[index]

    def _segment_fn(self, seg: TierSegment, head: bool):
        key = seg.spec(head)
        if key in self._fn_cache:
            return self._fn_cache[key]
        cfg = self.cfg
        lo, hi, branches = seg.layer_lo, seg.layer_hi, seg.branches

        def fn(params, x, pos, exited, chosen, caches):
            positions = pos[None].astype(jnp.int32)
            h = embed_decode(params, x, positions, cfg) if lo == 0 else x
            h, caches, _, collected = run_trunk(
                params, h, cfg, positions, caches,
                layer_range=(lo, hi), collect=branches,
            )
            bl = _branch_logits(params, collected, cfg)
            batch = x.shape[0]
            takes, ents = [], []
            for layer in branches:
                logits_b = bl[layer][:, 0]
                e = normalized_entropy(logits_b)
                take = (e < cfg.exit_threshold) & ~exited
                chosen = jnp.where(
                    take, jnp.argmax(logits_b, -1).astype(jnp.int32), chosen
                )
                exited = exited | take
                takes.append(take)
                ents.append(e)
            out = {
                "caches": caches,
                "exited": exited,
                "chosen": chosen,
                "take": jnp.stack(takes) if takes
                else jnp.zeros((0, batch), bool),
                "ents": jnp.stack(ents) if ents
                else jnp.zeros((0, batch), jnp.float32),
            }
            if head:
                hF = norm_apply(cfg.norm_type, params["final_norm"], h)
                logits = _unembed(params, hF, cfg)[:, 0]
                out["logits"] = logits
                out["chosen"] = jnp.where(
                    exited, chosen, jnp.argmax(logits, -1).astype(jnp.int32)
                )
                out["caches"] = dict(out["caches"])
                out["caches"]["length"] = caches["length"] + 1
            else:
                out["hidden"] = h
            return out

        jitted = jax.jit(fn)
        self._fn_cache[key] = jitted
        return jitted

    # -------------------------------------------------------------- step
    def step(self, tok: jax.Array, pos, caches: Any) -> tuple[TierStepResult, Any]:
        """One decode step across all tiers: exactly one host sync."""
        cfg = self.cfg
        batch = tok.shape[0]
        posj = jnp.asarray(pos, jnp.int32)
        exited = jnp.zeros((batch,), bool)
        chosen = jnp.zeros((batch,), jnp.int32)
        x: jax.Array = tok
        fetch: dict[str, Any] = {}
        seg_branches: list[tuple[int, tuple[int, ...]]] = []
        logits = None

        for i, seg in enumerate(self.segments):
            fn = self._fns[i]
            if fn is None:
                continue
            out = fn(self.params, x, posj, exited, chosen, caches)
            caches = out["caches"]
            exited, chosen = out["exited"], out["chosen"]
            if seg.branches:
                fetch[f"take{i}"] = out["take"]
                fetch[f"ents{i}"] = out["ents"]
                seg_branches.append((i, seg.branches))
            if i == self._head_idx:
                logits = out["logits"]
            else:
                x = out["hidden"]

        fetch["tokens"] = chosen
        fetch["exited"] = exited
        host = jax.device_get(fetch)  # the step's single device->host sync
        self.host_syncs += 1

        # Host-side bookkeeping on the fetched masks (no further syncs).
        exit_tier = np.full((batch,), -1, np.int32)
        branch_take: dict[int, np.ndarray] = {}
        branch_entropy: dict[int, np.ndarray] = {}
        for i, layers in seg_branches:
            for row, layer in enumerate(layers):
                mask = host[f"take{i}"][row]
                branch_take[layer] = mask
                branch_entropy[layer] = host[f"ents{i}"][row]
                exit_tier[mask] = i
        exited_run = np.zeros((batch,), bool)
        alive_after_seg = {}
        for i, seg in enumerate(self.segments):
            for layer in seg.branches:
                exited_run |= branch_take[layer]
            alive_after_seg[i] = int(batch - exited_run.sum())

        # Hops: one per cut that still has layers (or the head) downstream.
        shipped, nbytes = [], []
        for j in range(self._head_idx):
            cut = self.segments[j].layer_hi
            alive = alive_after_seg[j]
            shipped.append(alive)
            nbytes.append(alive * bytes_per_sequence(cfg, cut))

        result = TierStepResult(
            tokens=host["tokens"],
            exited=host["exited"],
            exit_tier=exit_tier,
            branch_take=branch_take,
            branch_entropy=branch_entropy,
            shipped_per_hop=tuple(shipped),
            bytes_per_hop=tuple(nbytes),
            tokens_dev=chosen,
            last_logits=logits,
        )
        return result, caches
