"""Unified K-tier execution runtime for BranchyNet serving.

One decode step crosses up to K tiers (device -> edge -> ... -> cloud).
Every tier runs a contiguous trunk segment, evaluates the side branches
that live strictly inside it, and ships survivors across its uplink.  The
monolithic :class:`~repro.serving.engine.ServingEngine` (K=1), the paper's
:class:`~repro.serving.partitioned.PartitionedServer` (K=2) and the
beyond-paper :class:`~repro.serving.multitier.MultiTierServer` (K>=3) are
all thin configurations of the same :class:`TierExecutor`.

Branch placement follows the paper's semantics (Sec. IV-B, Fig. 2(c)):

  * a branch sitting exactly at a cut is discarded — the residual stream
    ships immediately;
  * the final tier evaluates no side branches (the cloud classifies at the
    output layer), except in the single-tier case where the whole
    BranchyNet runs in one place.

Survivor compaction (compact -> run -> scatter)
-----------------------------------------------
The paper's cost model banks on downstream work shrinking with exits, so
downstream tiers must not burn FLOPs on masked-out rows.  With
``compaction="bucketed"`` (the default) every downstream tier segment is a
single fused jitted call that

  1. **compacts**: a stable device-resident ``argsort`` of the exit mask
     orders survivors first; the leading ``bucket`` rows (survivors plus,
     if the bucket is larger, already-exited padding rows) are gathered
     into a dense sub-batch — hidden state only.  KV caches stay
     full-batch resident: the sub-batch reads/writes its rows *in place*
     through the ``rows`` plumbing of :func:`repro.models.model.run_trunk`
     (per-sequence slot validity masks the skipped rows' holes later);
  2. **runs** the tier's trunk layers, branches and (on the last tier)
     the head on the ``(bucket, 1, d)`` sub-batch, so tier FLOPs scale
     with the padded survivor count instead of the full batch;
  3. **scatters** tokens / exit masks / entropies / logits back to
     original batch order — so the step still ends in exactly ONE
     device->host sync of the packed full-batch pytree, and
     :class:`TierStepResult`'s contract is unchanged.

Pipelined overlap (``overlap="pipelined"``)
-------------------------------------------
Serial mode charges one decode step the full chain latency: every tier's
compute plus, under ``simulate_network``, every hop's transfer, back to
back.  A real edge->cloud deployment overlaps tier j's uplink transfer
with tier j+1's compute and double-buffers decode steps: the edge starts
token t+1 as soon as token t is emitted, while token t's hidden-state
handoffs are still draining down the chain.  ``overlap="pipelined"``
reproduces that steady state.  Tier segments are dispatched eagerly (jax
dispatch is asynchronous, so tier j+1's jitted segment is enqueued the
moment tier j's hidden-state handoff is traced — nothing blocks on the
simulated wire), and the simulated per-hop transfers are moved off the
step's critical path onto per-hop link clocks: hop j's transfer for token
t occupies the link for ``transfer_j`` seconds starting when both the
payload has arrived (token t cleared hop j-1) and the link is free (token
t-1's transfer finished).  A step returns once the *previous* token's
transfers have fully drained (double-buffer depth 1), so steady-state
step wall time is the pipeline bottleneck ``max_j(compute_j,
transfer_j)`` instead of the serial sum.

One single-host caveat: every tier's segment runs on the *same* device
here, so tier computes serialize and the measured steady state is
``max(sum_j compute_j, max_j transfer_j)``.  The cost model's
``overlap=True`` bottleneck takes the max over *per-tier* computes — that
is the real multi-host deployment the solver plans for, where tier j and
tier j+1 compute concurrently on different machines.  The two agree
whenever transfers dominate (the regime the benchmark smoke asserts); on
compute-bound profiles the simulator cannot deliver the compute overlap
the model credits.

The pipelined contract extends the one-sync invariant: still exactly one
fetch per emitted token (the single device->host sync is unchanged, and
tokens / exit masks / per-hop byte accounting are bitwise identical to
serial mode — pipelining reorders only the simulated sleeps, never the
computation).  An overflow-retry step falls back to serial for that step:
the pipeline is drained first, the step re-runs with measured buckets and
pays its transfers inline (counted in ``pipeline_fallbacks``), and
pipelining resumes on the next step.  ``install`` (a repartition) and
``drain()`` also drain the pipeline so no old-plan transfer overlaps the
new plan.

Fault plane and degraded steps (``fault_model`` / ``hop_policy``)
-----------------------------------------------------------------
A hop in a real deployment drops, flaps and slows down; the runtime's
answer is the BranchyNet one — *answer from the deepest exit head below
the broken link* — rather than an exception.  Attaching a
:class:`~repro.serving.faults.LinkFaultModel` (and optionally a
:class:`~repro.serving.faults.HopPolicy`) arms a two-phase fault plane:

  * **Phase A (pre-dispatch, host-side, sync-free):** before any segment
    is dispatched, every hop the plan would cross is health-checked in
    order — circuit-breaker gate, then up to ``1 + max_retries``
    simulated attempts (each failing on a scripted flap, a sampled drop,
    or a worst-case-payload transfer-time estimate exceeding
    ``timeout_s``; retries charge exponential backoff).  All decisions
    are deterministic functions of ``(seed, fault-step, hop)`` — never
    of the batch's live trajectory — so fault traces replay bit-exactly
    and an overflow retry re-uses the same plan.
  * **Phase B (post-sync):** under ``simulate_network`` the surviving
    hops charge their (multiplier-scaled, spike-added) transfer time
    plus any retry overhead to the wall clock; a broken hop charges only
    the overhead its failed attempts burned.

The degraded-step contract:

  * **Healthy steps are bitwise untouched.**  With no fault model the
    code path is identical to before; with a benign model attached
    (no flaps/drops/spikes, multiplier 1) every token, exit mask, cache
    write and byte count is bitwise identical to a run without it.
  * On breaker-open or retry exhaustion at hop ``j``, the step runs only
    the segments up to the one holding the **deepest exit head at or
    below hop j's cut** (a branch sitting exactly at the cut — normally
    discarded — is re-enabled as the fallback head) and every still-live
    row is finalized from that head via the normal per-branch exit
    masking: rows that exited upstream keep their exact tokens, forced
    rows emit the fallback head's argmax.  The step still performs
    exactly ONE device->host sync, still bumps the cache clock once, and
    reports the forced rows in ``TierStepResult.degraded`` with
    ``exit_tier`` = the fallback tier.  Forced exits are *not* counted
    in ``branch_take`` (controller exit-probability estimates only ever
    see genuine threshold exits).
  * If no exit head exists at or below the broken hop, nothing useful
    can be computed: the step dispatches nothing (no sync), emits no
    tokens, and reports every live row in ``TierStepResult.failed`` —
    the scheduler retires (or requeues) those requests with a terminal
    ``failed`` status and reclaims their KV slots.
  * ``fault_events`` carries the replayable per-step trace (attempts,
    retries, breaker transitions) and ``degraded_hop`` the broken hop;
    the :class:`~repro.serving.controller.RepartitionController` ingests
    both to EWMA per-hop health and re-solve toward a cut that avoids
    the sick link (``TierSpec.availability`` prices it in the lattice).

Zero-uplink hops under ``simulate_network`` are part of the same
contract: a hop that must ship bytes but has no usable ``uplink_bps``
raises :class:`~repro.serving.faults.LinkDownError` when no fault model
is attached (previously it silently slept 0 s — a dead link looked
free), and degrades through the fault plane when one is.

Bucket ladder and the one-sync invariant.  jit needs static shapes, so
sub-batches are padded to :func:`repro.core.multitier.bucket_ladder`
(powers of two, plus the full batch).  The bucket for step ``t`` is chosen
host-side from step ``t-1``'s survivor counts (fetched in the same single
sync) — no extra mid-step sync.  Step 0 runs full-batch buckets.  If a
step's true survivors overflow the planned bucket (exit-rate spike), the
host detects it from the fetched masks and *re-runs the whole step* from
the entry caches with measured buckets until nothing overflows (at most K
runs): results are always bitwise faithful, at the cost of one extra sync
per (counted) ``overflow_retries`` iteration.

Defined divergence from the masked path: an exited sequence contributes
no downstream-tier KV for that step (the masked path, which runs every
row everywhere, does write it).  Downstream attention masks the hole via
per-sequence slot validity, so the semantics are deterministic and
independent of bucket/padding choices; single-step outputs are bitwise
identical to the masked path, multi-step outputs are identical whenever
an exited sequence does not later re-enter the downstream tiers.

Kernel-backed hot path (``use_kernels``)
----------------------------------------
``use_kernels=None`` (auto) turns the Pallas kernel suite on on TPU and
keeps the pure-jnp lowering elsewhere; ``True`` forces the kernels (CPU
runs them in interpret mode — the equivalence tests).  Inside every
segment function the flag swaps three hot spots, leaving the dataflow,
the one-sync contract and the emitted trajectory unchanged:

  * decode attention runs :func:`repro.kernels.ops.flash_decode`, which
    scalar-prefetches the survivor ``rows`` map and streams those rows
    straight out of the full-batch resident KV cache (the jnp path
    gathers ``cache["k"][rows]`` and hopes XLA fuses it);
  * the per-branch BranchyNet confidence test runs the fused
    :func:`repro.kernels.ops.entropy_exit_argmax` kernel — normalized
    entropy, threshold flag and exit token in ONE pass over the (B, V)
    branch logits, so exiting rows never materialize a separate
    softmax + argmax;
  * Mamba2 decode steps run :func:`repro.kernels.ops.ssd_update` with the
    same ``rows`` plumbing into the resident SSM state.

Kernels recompile per *bucket* exactly like the jnp segment functions
(the (spec, bucket) cache below); a survivor-count change within a bucket
never re-traces either path.

Batched exit heads (``batched_heads``)
--------------------------------------
A tier that keeps K branches historically evaluated them one at a time:
K branch-norm + unembedding projections (each re-streaming the shared
(D, V) unembedding) and K entropy/argmax decisions.  With
``batched_heads=True`` (the default) a segment evaluates ALL of its
heads jointly:

  * the kept branches' hiddens are stacked to (K, B, D); the per-branch
    norm params are applied to the stack (rmsnorm scales are gathered to
    (K, 1, D) and broadcast; nonparametric norms are parameter-free) and
    ONE einsum against the shared unembedding yields (K, B, V) logits —
    the unembedding's bandwidth is paid once, amortized over K heads
    (:func:`repro.models.model.branch_logits_stacked`);
  * the K confidence tests run as ONE decision — the multi-head fused
    :func:`repro.kernels.ops.entropy_exit_argmax_heads` kernel under
    ``use_kernels`` (grid gains a K dimension; per-head thresholds ride
    in SMEM), or one vectorized jnp pass otherwise.  Mesh-sharded
    segments always take the jnp lowering (``resolve_use_kernels``'s
    ``sharded=True`` contract), which partitions cleanly under SPMD.

The layout contract: heads are stacked in ascending branch-layer order;
each head's (entropy, flag, argmax) row is *independent* of the running
exit mask, so first-exit precedence is applied after the joint decision
exactly as the sequential loop applied it (``take = flag & ~exited`` per
head, in layer order) — tokens, exit masks, ``branch_take`` /
``branch_probe_mask`` and degraded-mode forced finalization are all
bitwise identical to ``batched_heads=False`` (asserted in
``tests/test_batched_heads.py``); the ``branch_entropy`` float
diagnostic matches to within a few ULP (XLA may tile the stacked
``(K*B, D) x (D, V)`` projection GEMM differently from the per-head
one on some device configurations).

Probe-cost semantics are unchanged: an all-heads probe step folds the
probe heads into the same stacked projection (kept + probe heads = one
launch), while a *sampled* probe (``probe_sample_frac`` < 1) stacks the
probe heads over the sampled rows only as a second, smaller joint
evaluation — probe FLOPs still price at the sampled sub-batch, never the
full batch.  The cost layer prices all of this through
:func:`repro.core.profiler.branch_head_cost` (``heads_batched=`` picks
the joint vs per-head roofline) feeding the ``head_cost`` term of
:func:`repro.core.multitier.expected_time_multitier`.

Bucket hints.  The bucket planned for a downstream tier comes from a
*windowed max* of the last ``hint_window`` steps' survivor counts
(default 8) inflated by ``bucket_headroom`` (a fraction; 0.0 = exact
fit).  ``hint_window=1, bucket_headroom=0.0`` reproduces the historical
last-step-only exact-fit policy; wider windows / headroom trade padding
waste for fewer ``overflow_retries`` under fluctuating exit rates.

Probe steps (exploration).  A plan only evaluates the branches it kept,
so drift detection is blind to discarded branches.  Setting
``probe_next = True`` (the :class:`RepartitionController`'s epsilon
schedule does this every ``explore_every_n`` steps) makes the *next*
step evaluate every ``cfg.branch_layers`` head — the extra branches are
report-only: their would-exit masks and entropies appear in
``branch_take`` / ``branch_entropy`` so the controller refreshes their
probabilities, but exits, tokens, caches and byte accounting are bitwise
those of a normal step.

Segment functions are cached by ``(spec, bucket)`` where spec is
``(layer_lo, layer_hi, branches, head, probe)``: a repartition that moves
one cut re-uses the jitted callables of every unchanged tier segment, and
a survivor-count change *within* a bucket re-jits nothing
(``trace_counts`` exposes this for tests).

Mesh-sharded tier segments (``mesh`` / ``sharding``)
----------------------------------------------------
A tier in a production fleet is a pod slice, not a chip.  Passing a device
``mesh`` (optionally with an explicit :class:`~repro.sharding.policy
.ShardingPolicy`; default :func:`~repro.sharding.policy.make_policy`)
turns every segment function into an SPMD program:

  * **params** are placed once at construction under the policy's
    per-architecture ``param_spec`` rules (attention heads / FFN hidden /
    MoE expert dim / vocab on the ``model`` axis, FSDP over ``data`` where
    configured, indivisible dims cleanly replicated);
  * **KV/SSM caches** are placed by :meth:`TierExecutor.shard_caches`
    (servers call it right after ``init_caches``) under ``cache_spec`` —
    kv-heads on ``model`` when divisible, else head_dim; sharded layouts
    then persist across decode steps through XLA's propagation;
  * **activations** inside every segment fn are constrained through the
    :mod:`repro.sharding.ctx` context, which the model stack's existing
    ``constrain`` call sites pick up at trace time;
  * **kernels** resolve to the pure-jnp lowering
    (``resolve_use_kernels(..., sharded=True)``): the Pallas kernels are
    single-device programs and must not see a mesh-global batch.

The sharded-segment contract: every unsharded invariant holds — exactly
one host sync per decode step, survivor compaction with the same bucket
ladder, the (spec, bucket) segment cache (hot-swapping a cut never
re-jits an unchanged sharded segment), per-request trajectory isolation —
and the *token/exit-mask trajectory* matches the single-device runtime.
Logits are not bitwise identical: SPMD partial-sum all-reduces reorder
float accumulation, so equivalence is at the argmax/threshold-decision
level (the sharded equivalence tests pin exact token and exit-mask
trajectories over full decode runs).

On one host every segment shares the same mesh (the single-host SPMD
caveat, like the pipelined-overlap one above): "which tier is sharded" is
a cost-model property carried by ``TierSegment.devices`` /
``TierSpec.devices``.  The cost model prices a sharded tier as per-layer
compute scaled ``1/devices`` plus an intra-tier ring-all-reduce term
``2 * 2*(d-1)/d * alpha_i / ici_bps`` per layer (two collectives — the
attention-out and MLP-down partial sums), so ``solve_multitier`` can
trade "shard tier j over d chips" against "add a hop"; see
:mod:`repro.core.multitier`.

Continuous batching (request slots)
-----------------------------------
The executor also serves as the data plane of the request scheduler
(:mod:`repro.serving.scheduler`): the batch dimension becomes ``B`` KV
*slots* whose occupants change over time.  Three extensions make that
possible without ever reshaping a cache or re-jitting a segment:

  * ``step(..., pos=(B,), active=(B,))`` — per-sequence absolute
    positions (each request decodes at its own RoPE position and ring
    slot) and a live mask: dead slots enter the step pre-exited, so the
    entry tier masks them and downstream compaction drops them — the
    bucket ladder naturally tracks live occupancy;
  * :meth:`TierExecutor.prefill_rows` — admit waiting prompts by
    prefilling them *into* freed cache rows in place (each row ends
    exactly as a fresh solo prefill: stale slots reset to empty);
  * :meth:`TierExecutor.reset_rows` — optional retirement hygiene that
    invalidates a row's slots without touching its neighbors.

The invariant all three preserve: a request's token/exit trajectory is
bitwise identical to running it alone from its admission state,
independent of which slot it recycled or who occupied it before (the
scheduler tests pin this for K in {1, 2, 3}, compaction on/off, and the
kernel path in interpret mode).
"""

from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.calibration import normalized_entropy
from repro.core.multitier import bucket_for, bucket_ladder
from repro.kernels import ops as kernel_ops
from repro.serving.faults import (
    CircuitBreaker,
    FaultEvent,
    HEALTHY,
    HopOutcome,
    HopPolicy,
    LinkDownError,
    LinkFaultModel,
    attempt_hop,
)
from repro.launch.mesh import mesh_devices
from repro.models.layers import norm_apply
from repro.sharding.ctx import activation_sharding
from repro.sharding.policy import make_policy
from repro.models.model import (
    _unembed,
    branch_logits_per_head,
    branch_logits_stacked,
    embed_decode,
    prefill,
    run_trunk,
    trunk_layout,
)

__all__ = [
    "TierSegment",
    "TierStepResult",
    "TierExecutor",
    "HopCompaction",
    "segments_for_cuts",
    "bytes_per_sequence",
    "transfer_seconds",
    "TOKEN_ID_BYTES",
    "LinkDownError",
]

#: Per-sequence payload of a hop taken before any trunk layer ran: the raw
#: token id (the prompt itself crossed at prefill time).
TOKEN_ID_BYTES = 4.0


@dataclasses.dataclass(frozen=True)
class TierSegment:
    """One tier's share of the trunk: layers ``[layer_lo, layer_hi)``
    (absolute, 0-based), the 1-based branch collect points it evaluates,
    the uplink to the next tier (bits/s; ``None`` on the last tier), and
    the tier's shard width (``devices > 1`` = the tier is a mesh slice;
    carried into the segment-fn cache key so a repartition that changes a
    tier's width recompiles exactly that tier)."""

    name: str
    layer_lo: int
    layer_hi: int
    branches: tuple[int, ...] = ()
    uplink_bps: float | None = None
    devices: int = 1

    @property
    def is_empty(self) -> bool:
        return self.layer_hi == self.layer_lo

    def spec(self, head: bool) -> tuple:
        """Cache key for the compiled segment function."""
        return (self.layer_lo, self.layer_hi, self.branches, head,
                self.devices)


@dataclasses.dataclass(frozen=True)
class HopCompaction:
    """Per-hop compaction accounting: who survived, what shape ran."""

    survivors: int  # true survivors crossing the hop
    bucket: int  # static sub-batch width the downstream tier ran

    @property
    def padded_waste(self) -> int:
        """Padding rows the downstream tier computed but did not need."""
        return self.bucket - self.survivors


def transfer_seconds(nbytes: float, uplink_bps: float | None) -> float:
    """Wall seconds to ship ``nbytes`` over a hop, with the runtime's
    zero-uplink policy: an unset/zero bandwidth reports 0.0 for *byte
    accounting* (the hop is unaccounted, not priced infinite — the cost
    model prices unusable hops at inf via
    :func:`repro.core.multitier._hop_seconds`).  The wall-clock
    ``simulate_network`` path never reaches here with a dead uplink and
    a nonzero payload: :meth:`TierExecutor.step` raises
    :class:`~repro.serving.faults.LinkDownError` (no fault model) or
    degrades through the fault plane (model attached) instead of
    pricing the dead hop free."""
    if not uplink_bps or uplink_bps <= 0.0:
        return 0.0
    return nbytes * 8.0 / uplink_bps


def bytes_per_sequence(cfg: ModelConfig, cut_layer: int) -> float:
    """Payload one surviving sequence ships at a cut after ``cut_layer``
    (1-based; 0 = before any trunk layer -> raw token id)."""
    if cut_layer == 0:
        return TOKEN_ID_BYTES
    return cfg.d_model * 2.0  # bf16 residual stream


def segments_for_cuts(
    cfg: ModelConfig,
    cuts: Sequence[int],
    *,
    names: Sequence[str] | None = None,
    uplinks: Sequence[float] | None = None,
    devices: Sequence[int] | None = None,
) -> tuple[TierSegment, ...]:
    """Generic plan -> runtime adapter: monotone 1-based cut points
    ``(c_1 .. c_{K-1})`` become K :class:`TierSegment` specs.

    Tier j runs layers ``(c_j, c_{j+1}]`` (1-based).  Branch placement per
    the module docstring: strictly inside a tier, never on the final tier
    of a K>=2 stack, and a branch at a cut is discarded.
    """
    total = sum(n for _, _, n in trunk_layout(cfg))
    bounds = (0, *(int(c) for c in cuts), total)
    if any(b > a for a, b in zip(bounds[1:], bounds[:-1])):
        raise ValueError(f"cuts must be non-decreasing in [0, {total}]: {cuts}")
    k = len(bounds) - 1
    segs = []
    for j in range(k):
        lo, hi = bounds[j], bounds[j + 1]
        if j == k - 1 and k > 1:
            brs: tuple[int, ...] = ()  # the cloud evaluates no branches
        else:
            # Strict at the cut (branch there is discarded); at the trunk
            # end there is no cut, so the deepest branch is evaluated.
            brs = tuple(
                b for b in cfg.branch_layers
                if lo < b and (b <= hi if hi == total else b < hi)
            )
        name = names[j] if names else f"tier{j}"
        up = uplinks[j] if uplinks and j < len(uplinks) else None
        dev = int(devices[j]) if devices and j < len(devices) else 1
        segs.append(
            TierSegment(name, lo, hi, brs, up if j < k - 1 else None, dev)
        )
    return tuple(segs)


@dataclasses.dataclass
class TierStepResult:
    """Everything a server needs from one decode step, fetched in one
    device->host sync (except the device-resident feedback arrays).

    In compacted mode, ``branch_entropy`` rows and ``last_logits`` rows of
    sequences that exited upstream and were not selected as padding are
    zero (they were never computed); ``tokens``/``exited``/``branch_take``
    are always exact for every sequence.
    """

    tokens: np.ndarray  # (B,) chosen token per sequence
    exited: np.ndarray  # (B,) bool — exited at some side branch
    exit_tier: np.ndarray  # (B,) int32 tier index of the exit, -1 = main head
    branch_take: dict[int, np.ndarray]  # layer -> (B,) bool first-exit mask
    branch_entropy: dict[int, np.ndarray]  # layer -> (B,) normalized entropy
    shipped_per_hop: tuple[int, ...]  # survivors crossing each executed hop
    bytes_per_hop: tuple[float, ...]
    tokens_dev: jax.Array  # device copy for the next step's input
    last_logits: jax.Array  # (B, V) main-head logits, device-resident
    compaction: tuple[HopCompaction, ...] = ()  # per executed hop
    sim_transfer_s: tuple[float, ...] = ()  # simulated uplink time per hop
    #: Sequences live at step entry (== B under lock-step; the scheduler's
    #: occupied slots under continuous batching).  ``active`` is the host
    #: mask the step ran with (None = every row live); dead slots read
    #: exited=True and garbage tokens — callers index by their live slots.
    live: int = 0
    active: np.ndarray | None = None
    #: Sampled probe steps only: layer -> (B,) bool mask of the rows whose
    #: branch head was actually evaluated (``probe_sample_frac`` < 1) — the
    #: controller must count arrivals over covered rows only.  Empty for
    #: full probes and normal steps.
    branch_probe_mask: dict[int, np.ndarray] = dataclasses.field(
        default_factory=dict
    )
    #: Fault-plane outputs (see the module docstring's degraded-step
    #: contract).  ``degraded`` marks rows finalized from the fallback
    #: exit head below a broken hop (their token is real, just shallower
    #: than planned); ``failed`` marks rows that could not emit at all
    #: (no exit head at or below the broken hop).  Both None on healthy
    #: steps with no fault plane armed.  ``fault_events`` is the step's
    #: replayable trace; ``degraded_hop`` the hop that broke (None =
    #: healthy step).
    degraded: np.ndarray | None = None
    failed: np.ndarray | None = None
    fault_events: tuple[FaultEvent, ...] = ()
    degraded_hop: int | None = None


class TierExecutor:
    """Compiles one jitted segment per (tier, bucket) and runs the K-hop
    decode step with survivor compaction at every hop.

    ``install`` swaps the segment list in place; segment functions are
    cached by (spec, bucket) so an unchanged tier is never re-jitted.

    ``compaction``: "bucketed" (default) runs each downstream tier on a
    dense survivor sub-batch padded to the bucket ladder; "off" keeps the
    legacy masked full-batch execution on every tier.

    ``simulate_network``: opt-in wall-clock simulation — after the step's
    single host sync, sleep for each hop's ``shipped_bytes * 8 /
    uplink_bps`` so measured step time (not just byte accounting) reflects
    the bandwidth cliff.

    ``overlap``: "serial" (default) pays the simulated transfers inline, so
    a step costs the chain sum; "pipelined" runs the transfers on per-hop
    link clocks overlapped with the next step's compute and double-buffers
    decode steps (see the module docstring) — steady-state step wall time
    is the bottleneck stage, tokens stay bitwise identical.

    ``use_kernels``: dispatch the decode hot path to the Pallas kernels
    (flash_decode / fused entropy-exit+argmax / ssd_update).  None = the
    config's ``cfg.use_kernels``; a still-None config means auto (kernels
    on TPU, jnp elsewhere).

    ``hint_window`` / ``bucket_headroom``: bucket hint policy — plan each
    downstream tier's bucket from the max survivor count of the last
    ``hint_window`` steps, inflated by ``bucket_headroom`` (fractional;
    see the module docstring).

    ``mesh`` / ``sharding``: execute the segment fns SPMD across a device
    mesh (see the module docstring's sharded-segment contract).  Params
    are placed at construction; callers place caches through
    :meth:`shard_caches`.  ``sharding=None`` derives the policy via
    :func:`~repro.sharding.policy.make_policy`.  A 1-device mesh is
    treated as unsharded.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        segments: Sequence[TierSegment],
        *,
        compaction: str = "bucketed",
        simulate_network: bool = False,
        overlap: str = "serial",
        use_kernels: bool | None = None,
        batched_heads: bool = True,
        hint_window: int = 8,
        bucket_headroom: float = 0.0,
        mesh: Any = None,
        sharding: Any = None,
        fault_model: LinkFaultModel | None = None,
        hop_policy: HopPolicy | None = None,
    ):
        if compaction not in ("bucketed", "off"):
            raise ValueError(f"unknown compaction mode: {compaction!r}")
        if overlap not in ("serial", "pipelined"):
            raise ValueError(f"unknown overlap mode: {overlap!r}")
        if hint_window < 1:
            raise ValueError(f"hint_window must be >= 1: {hint_window}")
        if bucket_headroom < 0.0:
            raise ValueError(f"bucket_headroom must be >= 0: {bucket_headroom}")
        self.cfg = cfg
        self.mesh = mesh
        self.sharded = mesh is not None and mesh_devices(mesh) > 1
        self.policy = None
        if self.sharded:
            self.policy = (
                sharding if sharding is not None else make_policy(mesh, cfg)
            )
            params = self.policy.shard_params(params)
        self.params = params
        self.compaction = compaction
        self.simulate_network = simulate_network
        self.overlap = overlap
        self.use_kernels = kernel_ops.resolve_use_kernels(
            cfg.use_kernels if use_kernels is None else use_kernels,
            sharded=self.sharded,
        )
        #: Batched exit heads (default): a segment's kept branches + probe
        #: heads evaluate as ONE stacked (K, B, D) projection against the
        #: shared unembedding and ONE multi-head entropy-exit launch.
        #: ``False`` keeps the sequential per-head lowering — the parity
        #: baseline tests and benchmarks compare against; both paths are
        #: bitwise identical (see the module docstring).
        self.batched_heads = bool(batched_heads)
        self.hint_window = hint_window
        self.bucket_headroom = bucket_headroom
        #: Set to make the NEXT step a probe: every cfg.branch_layers head
        #: is evaluated and reported (would-exit masks + entropies) without
        #: touching exits/tokens/caches.  Consumed by step().
        self.probe_next = False
        #: Fraction of the batch a probe step evaluates the extra branch
        #: heads on (1.0 = every row).  Sampled probes price exploration at
        #: a sub-batch of head FLOPs; the evaluated rows are reported in
        #: ``TierStepResult.branch_probe_mask`` so the controller counts
        #: arrivals over covered rows only.  On a compacted tier the sample
        #: indexes the dense sub-batch (the survivor permutation lives on
        #: device), so *which* batch rows a probe covers follows the
        #: compaction order — always reported, estimates stay unbiased.
        self.probe_sample_frac = 1.0
        self._probe_offset = 0  # rotation cursor so samples cycle the batch
        self.total_layers = sum(n for _, _, n in trunk_layout(cfg))
        self._fn_cache: dict[tuple, Any] = {}
        self.host_syncs = 0
        self.overflow_retries = 0
        #: pipelined steps that fell back to serial (overflow retry drained
        #: the pipeline and paid its transfers inline).
        self.pipeline_fallbacks = 0
        #: (spec, bucket) -> number of jax traces (a survivor-count change
        #: within a bucket must not add one).
        self.trace_counts: dict[tuple, int] = {}
        #: Pipelined-mode simulated network state: per-hop link-free wall
        #: clocks, and when the previous step's last transfer completes.
        self._link_free: list[float] = []
        self._inflight_done = 0.0
        # Fault plane (armed iff a fault model is attached; a policy alone
        # arms it with an all-healthy model so timeouts/breakers still
        # apply to the real uplinks).
        if fault_model is None and hop_policy is not None:
            fault_model = LinkFaultModel()
        self.fault_model = fault_model
        self.hop_policy = (
            hop_policy if hop_policy is not None
            else (HopPolicy() if fault_model is not None else None)
        )
        #: Per-hop circuit breakers, keyed by hop index.  Hop identity is
        #: the tier-boundary position, which survives repartitions —
        #: breaker state deliberately persists across ``install`` so a
        #: re-solve cannot reset an open breaker.
        self._breakers: dict[int, CircuitBreaker] = {}
        #: The fault plane's step clock (drives seeded draws and flap
        #: windows); advances once per ``step()`` when the plane is armed.
        self.fault_step = 0
        self.degraded_steps = 0
        self.failed_steps = 0
        self.fault_retries = 0
        self.install(segments)

    # -------------------------------------------------------------- plan
    def install(self, segments: Sequence[TierSegment]) -> None:
        """Install a new tier plan, re-using compiled unchanged segments.
        Outstanding pipelined transfers are drained first so no old-plan
        hop overlaps the new plan."""
        self.drain()
        segments = tuple(segments)
        if not segments or segments[0].layer_lo != 0:
            raise ValueError("first segment must start at layer 0")
        if segments[-1].layer_hi != self.total_layers:
            raise ValueError("last segment must end at the trunk tail")
        for a, b in zip(segments, segments[1:]):
            if a.layer_hi != b.layer_lo:
                raise ValueError("segments must tile the trunk contiguously")
        self.segments = segments
        # The final head runs on the last tier that runs any layers.
        self._head_idx = max(
            i for i, s in enumerate(segments) if not s.is_empty
        )
        self._fns = [
            self._segment_fn(seg, head=(i == self._head_idx))
            if not seg.is_empty else None
            for i, seg in enumerate(segments)
        ]
        # Survivor-count hints (segment index -> windowed-max survivor
        # count over the last hint_window steps) are plan-specific; a fresh
        # plan starts conservatively at full batch.  ``_hints`` is the
        # effective per-segment hint the planner consumes (tests may pin
        # it); ``_hint_hist`` is the observation window feeding it.
        self._hints = {}
        self._hint_hist = {}

    def segment_fn(self, index: int):
        """The compiled full-batch callable for segment ``index``
        (None if the segment is empty)."""
        return self._fns[index]

    # ---------------------------------------------------------- sharding
    def shard_caches(self, caches: Any) -> Any:
        """Place a freshly initialized cache pytree per the sharding
        policy's cache rules (no-op when the executor has no mesh).
        Servers call this once right after ``init_caches``; the layouts
        then persist across decode steps through XLA's propagation."""
        if not self.sharded:
            return caches
        return self.policy.shard_caches(caches)

    def _jit(self, fn):
        """``jax.jit`` with the executor's activation-sharding context
        active at trace time (jit executes the traced body once), so the
        model stack's ``constrain`` call sites emit real constraints on a
        sharded executor and stay no-ops otherwise."""
        if not self.sharded:
            return jax.jit(fn)
        pol = self.policy

        def traced(*args):
            with activation_sharding(pol.mesh, pol.batch_axes,
                                     pol.model_axis):
                return fn(*args)

        return jax.jit(traced)

    def _segment_fn(
        self,
        seg: TierSegment,
        head: bool,
        bucket: int | None = None,
        probe: tuple[int, ...] = (),
        probe_m: int | None = None,
        degrade: int | None = None,
    ):
        """Build (or fetch) the jitted callable for one tier segment.

        ``bucket=None``: masked full-batch execution (the entry tier, and
        every tier in compaction="off" mode).  ``bucket=b``: the fused
        compact(b) -> run -> scatter step described in the module
        docstring.  ``probe``: extra branch layers evaluated report-only
        (would-exit masks + entropies; exits/tokens untouched);
        ``probe_m`` samples those heads on ``probe_m`` rows instead of the
        whole sub-batch (the evaluated rows come back as a coverage mask).
        ``degrade``: a degraded step's terminal segment — after the plan
        branches run their normal exit masking, every still-unexited row
        is force-finalized from the exit head at 1-based layer ``degrade``
        (the deepest head at or below the broken hop; re-enables a head
        sitting exactly at the cut), and the cache step clock is bumped
        here since no head tier runs.  All variants share the signature
        ``fn(params, x, pos, exited, chosen, caches[, probe_rows])`` with
        full-batch x; ``pos`` is the shared () step position or the
        continuous-batching per-sequence (B,) positions.
        """
        key = ((*seg.spec(head), probe, probe_m, degrade), bucket)
        if key in self._fn_cache:
            return self._fn_cache[key]
        cfg = self.cfg
        lo, hi, branches = seg.layer_lo, seg.layer_hi, seg.branches
        plan_set = frozenset(branches)
        probe_set = frozenset(probe)
        extra = () if degrade is None else (degrade,)
        eval_layers = tuple(sorted({*branches, *probe, *extra}))
        use_kernels = self.use_kernels
        batched_heads = self.batched_heads
        trace_counts = self.trace_counts

        def exit_decision(logits_b, ex):
            """(take mask, entropy, exit token) for one branch head.  The
            kernel path fuses all three into one pass over (B, V); both
            paths break argmax ties identically (first occurrence), so the
            emitted token is bitwise path-independent."""
            if use_kernels:
                e, flag, btok = kernel_ops.entropy_exit_argmax(
                    logits_b, cfg.exit_threshold
                )
            else:
                e = normalized_entropy(logits_b)
                flag = e < cfg.exit_threshold
                btok = jnp.argmax(logits_b, -1).astype(jnp.int32)
            return flag & ~ex, e, btok

        def head_decisions(layers, logits_k):
            """Per-head (entropy, raw exit flag, argmax token) for a
            stacked (K, B, V) head pile in ONE launch (the multi-head
            kernel; jnp reductions over the trailing axis otherwise —
            the fallback sharded segments resolve to).  Per-head slices
            are bitwise the single-head ``exit_decision`` inputs: the
            flag is mask-independent, so precedence can be applied to
            the cheap (B,) rows afterwards."""
            if use_kernels:
                e, flag, btok = kernel_ops.entropy_exit_argmax_heads(
                    logits_k, cfg.exit_threshold
                )
            else:
                e = normalized_entropy(logits_k)
                flag = e < cfg.exit_threshold
                btok = jnp.argmax(logits_k, -1).astype(jnp.int32)
            return {
                layer: (e[r], flag[r], btok[r])
                for r, layer in enumerate(layers)
            }

        def fn(params, x, pos, exited, chosen, caches, probe_rows=None):
            trace_counts[key] = trace_counts.get(key, 0) + 1
            batch = x.shape[0]
            # Shared () step position -> (1,); continuous-batching (B,)
            # per-sequence positions -> (B, 1) (each row decodes at its own
            # absolute position).
            positions = (
                pos[None].astype(jnp.int32) if pos.ndim == 0
                else pos[:, None].astype(jnp.int32)
            )
            if bucket is None:
                xb, ex, ch, rows, rows_rw = x, exited, chosen, None, None
            else:
                # ---- compact: survivors first (stable -> original order),
                # then already-exited padding rows up to the bucket width.
                order = jnp.argsort(exited, stable=True)
                rows = order[:bucket]
                xb = x[rows]
                ex, ch = exited[rows], chosen[rows]
                if positions.ndim == 2:
                    positions = positions[rows]
                # Padding rows read clamped garbage (discarded) and carry
                # an out-of-bounds sentinel so their cache writes drop:
                # downstream KV validity is a pure function of exits, not
                # of which rows happened to pad the bucket.
                rows_rw = jnp.where(ex, batch, rows).astype(jnp.int32)
            h = embed_decode(params, xb, positions, cfg) if lo == 0 else xb
            h, new_caches, _, collected = run_trunk(
                params, h, cfg, positions, caches,
                layer_range=(lo, hi), collect=eval_layers, rows=rows_rw,
                use_kernels=use_kernels,
            )
            sub = xb.shape[0]
            if probe_m is not None:
                # Sampled probe: the extra heads run on probe_m rows only.
                # probe_rows are original-batch indices; fold them into the
                # sub-batch coordinate space (compacted tiers run a dense
                # permutation of it) and remember which batch rows that
                # covers for the report.
                pr_idx = probe_rows.astype(jnp.int32) % sub
            else:
                pr_idx = None
            if batched_heads:
                # ---- batched heads: the segment's kept branches, probe
                # heads and degrade fallback evaluate as ONE stacked
                # (K, sub, D) projection against the shared unembedding +
                # ONE multi-head entropy/flag/argmax launch.  A sampled
                # probe (probe_m) runs at a different width, so its heads
                # form a second (K_probe, probe_m, D) stack — still one
                # projection + one launch for all probe heads.  Exit
                # precedence is applied afterwards on the per-head (B,)
                # rows in the same sorted-layer order as the sequential
                # path; the per-head kernel outputs are mask-independent,
                # so the result is bitwise identical.
                main_layers = (
                    eval_layers if probe_m is None
                    else tuple(sorted({*branches, *extra}))
                )
                mls, mlg = branch_logits_stacked(
                    params, collected, cfg, main_layers
                )
                dec = {} if mlg is None else head_decisions(mls, mlg[:, :, 0])
                pdec = dec
                if probe_m is not None and probe:
                    probe_hidden = {l: collected[l][pr_idx] for l in probe}
                    pls, plg = branch_logits_stacked(
                        params, probe_hidden, cfg, tuple(sorted(probe))
                    )
                    pdec = head_decisions(pls, plg[:, :, 0])
                bl = blp = None
            else:
                # ---- sequential reference path: one projection + one
                # exit-decision launch per head (the parity baseline).
                if probe_m is not None:
                    plan_hidden = {
                        l: collected[l] for l in {*branches, *extra}
                    }
                    probe_hidden = {l: collected[l][pr_idx] for l in probe}
                    bl = branch_logits_per_head(params, plan_hidden, cfg)
                    blp = branch_logits_per_head(params, probe_hidden, cfg)
                else:
                    bl = branch_logits_per_head(params, collected, cfg)
                    blp = bl
            takes, ents, ptakes, pents = [], [], [], []
            for layer in eval_layers:
                if layer in plan_set:
                    if batched_heads:
                        e, flag, btok = dec[layer]
                        take = flag & ~ex
                    else:
                        take, e, btok = exit_decision(bl[layer][:, 0], ex)
                    ch = jnp.where(take, btok, ch)
                    ex = ex | take
                    takes.append(take)
                    ents.append(e)
                elif layer in probe_set:
                    # probe: report-only, never alters the trajectory
                    exp = ex if pr_idx is None else ex[pr_idx]
                    if batched_heads:
                        e, flag, _ = pdec[layer]
                        take = flag & ~exp
                    else:
                        take, e, _ = exit_decision(blp[layer][:, 0], exp)
                    ptakes.append(take)
                    pents.append(e)
                # else: the degrade fallback head, consumed below.
            if degrade is not None:
                # Degraded terminal segment: force-finalize every
                # still-unexited row from the fallback head (threshold
                # ignored — the link below is dead, this IS the answer)
                # and advance the cache step clock, which normally
                # happens on the head tier.  The batched path reads the
                # fallback token from the stacked launch's argmax row
                # (bitwise jnp.argmax, see kernels/entropy_exit.py).
                dtok = (
                    dec[degrade][2] if batched_heads
                    else jnp.argmax(bl[degrade][:, 0], -1).astype(jnp.int32)
                )
                ch = jnp.where(ex, ch, dtok)
                ex = jnp.ones_like(ex)
                new_caches = dict(new_caches)
                new_caches["length"] = caches["length"] + 1
            psub = sub if probe_m is None else probe_m
            take_s = jnp.stack(takes) if takes else jnp.zeros((0, sub), bool)
            ents_s = (
                jnp.stack(ents) if ents else jnp.zeros((0, sub), jnp.float32)
            )
            ptake_s = (
                jnp.stack(ptakes) if ptakes else jnp.zeros((0, psub), bool)
            )
            pents_s = (
                jnp.stack(pents) if pents else jnp.zeros((0, psub), jnp.float32)
            )
            out: dict[str, Any] = {"caches": new_caches}
            logits = None
            if head:
                hF = norm_apply(cfg.norm_type, params["final_norm"], h)
                logits = _unembed(params, hF, cfg)[:, 0]
                ch = jnp.where(
                    ex, ch, jnp.argmax(logits, -1).astype(jnp.int32)
                )
                out["caches"] = dict(out["caches"])
                out["caches"]["length"] = caches["length"] + 1
            if bucket is None:
                out["exited"], out["chosen"] = ex, ch
                out["take"], out["ents"] = take_s, ents_s
                if probe_m is None:
                    out["ptake"], out["pents"] = ptake_s, pents_s
                else:
                    out["ptake"] = (
                        jnp.zeros((len(probe), batch), bool)
                        .at[:, pr_idx].set(ptake_s)
                    )
                    out["pents"] = (
                        jnp.zeros((len(probe), batch), jnp.float32)
                        .at[:, pr_idx].set(pents_s)
                    )
                    out["pcover"] = (
                        jnp.zeros((batch,), bool).at[pr_idx].set(True)
                    )
                if head:
                    out["logits"] = logits
                elif degrade is None:
                    out["hidden"] = h
            else:
                # ---- scatter back to original batch order (device-side).
                nbr = len(branches)
                out["exited"] = exited.at[rows].set(ex)
                out["chosen"] = chosen.at[rows].set(ch)
                out["take"] = (
                    jnp.zeros((nbr, batch), bool).at[:, rows].set(take_s)
                )
                out["ents"] = (
                    jnp.zeros((nbr, batch), jnp.float32).at[:, rows].set(ents_s)
                )
                pcols = rows if probe_m is None else rows[pr_idx]
                out["ptake"] = (
                    jnp.zeros((len(probe), batch), bool)
                    .at[:, pcols].set(ptake_s)
                )
                out["pents"] = (
                    jnp.zeros((len(probe), batch), jnp.float32)
                    .at[:, pcols].set(pents_s)
                )
                if probe_m is not None:
                    out["pcover"] = (
                        jnp.zeros((batch,), bool).at[pcols].set(True)
                    )
                if head:
                    out["logits"] = (
                        jnp.zeros((batch, logits.shape[-1]), logits.dtype)
                        .at[rows].set(logits)
                    )
                elif degrade is None:
                    out["hidden"] = (
                        jnp.zeros((batch, 1, h.shape[-1]), h.dtype)
                        .at[rows].set(h)
                    )
            return out

        jitted = self._jit(fn)
        self._fn_cache[key] = jitted
        return jitted

    # --------------------------------------------------------- pipelining
    def drain(self) -> None:
        """Block until every outstanding pipelined simulated transfer has
        completed, then reset the link clocks.  No-op in serial mode or
        when nothing is in flight."""
        target = max([self._inflight_done, *self._link_free], default=0.0)
        wait = target - time.perf_counter()
        if wait > 0:
            time.sleep(wait)
        self._link_free = []
        self._inflight_done = 0.0

    def _pipeline_transfers(self, sim: tuple[float, ...]) -> None:
        """Schedule this step's simulated hop transfers on the per-hop link
        clocks and pace the decode loop at double-buffer depth 1: the step
        returns once the *previous* step's transfers have drained, so the
        steady-state step period is the pipeline bottleneck stage."""
        now = time.perf_counter()
        if len(self._link_free) < len(sim):
            self._link_free += [0.0] * (len(sim) - len(self._link_free))
        arrive = now  # payload leaves the entry tier at the sync
        for j, t in enumerate(sim):
            # The link takes the payload when it has both arrived (cleared
            # hop j-1) and the link is free (previous token's hop j done).
            depart = max(arrive, self._link_free[j])
            self._link_free[j] = depart + t
            arrive = self._link_free[j]
        prev_done, self._inflight_done = self._inflight_done, arrive
        wait = prev_done - time.perf_counter()
        if wait > 0:
            time.sleep(wait)

    # ------------------------------------------------- request admission
    def prefill_rows(
        self, caches: Any, tokens: jax.Array, rows
    ) -> tuple[Any, jax.Array]:
        """Admit a block of waiting prompts into freed cache rows.

        ``tokens`` (n, P) prompt token ids; prompt row ``i`` prefills into
        row ``rows[i]`` of the resident full-batch caches *in place* — the
        row ends exactly as a fresh solo prefill of that prompt (stale
        slots from the previous occupant reset to empty), so no cache
        reshape or re-jit of any decode segment is ever needed.  Rows with
        an out-of-bounds sentinel (>= batch) drop their writes, letting
        callers pad admission groups to reusable (P, n) jit shapes.

        Returns (new caches, first decode-step input token per prompt row
        (n,), device-resident — admission performs no host sync)."""
        tokens = jnp.asarray(tokens, jnp.int32)
        n, plen = tokens.shape
        key = ("prefill", plen, n)
        fn = self._fn_cache.get(key)
        if fn is None:
            cfg = self.cfg
            trace_counts = self.trace_counts

            def prefill_fn(params, toks, rows_, caches_):
                trace_counts[key] = trace_counts.get(key, 0) + 1
                logits, new_caches = prefill(
                    params, {"tokens": toks}, cfg, caches_, rows=rows_
                )
                tok0 = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
                return tok0, new_caches

            fn = self._jit(prefill_fn)
            self._fn_cache[key] = fn
        tok0, caches = fn(
            self.params, tokens, jnp.asarray(rows, jnp.int32), caches
        )
        return caches, tok0

    def reset_rows(self, caches: Any, rows) -> Any:
        """Mark cache rows empty without moving anything: per-sequence slot
        validity (``pos``) -> -1 and SSM/conv state -> 0 for the given rows
        (K/V payloads stay in place — unreachable once their slot is
        invalid).  Retirement hygiene; admission prefill also resets its
        row implicitly, so this is optional between occupants."""
        rows = jnp.asarray(rows, jnp.int32)
        key = ("reset", int(rows.shape[0]))
        fn = self._fn_cache.get(key)
        if fn is None:

            def reset_fn(caches_, rows_):
                def walk(tree):
                    out = {}
                    for k, v in tree.items():
                        if isinstance(v, dict):
                            out[k] = walk(v)
                        elif k == "pos":
                            out[k] = v.at[:, rows_].set(-1, mode="drop")
                        elif k in ("conv", "ssm"):
                            out[k] = v.at[:, rows_].set(
                                jnp.zeros((), v.dtype), mode="drop"
                            )
                        else:
                            out[k] = v
                    return out

                return walk(caches_)

            fn = jax.jit(reset_fn)
            self._fn_cache[key] = fn
        return fn(caches, rows)

    # -------------------------------------------------------------- step
    def _plan_buckets(self, batch: int) -> dict[int, int]:
        """Host-side bucket plan for this step: the windowed-max survivor
        hint per downstream segment (full batch where no hint exists yet),
        inflated by ``bucket_headroom`` and rounded up the bucket ladder."""
        if self.compaction != "bucketed":
            return {}
        executed = [
            i for i, s in enumerate(self.segments) if not s.is_empty
        ]
        buckets = {}
        for i in executed[1:]:
            hint = self._hints.get(i, batch)
            padded = min(batch, math.ceil(hint * (1.0 + self.bucket_headroom)))
            buckets[i] = bucket_for(padded, batch)
        return buckets

    def _observe_hints(self, entering: dict[int, int]) -> None:
        """Feed this step's entering-survivor counts into the hint window
        and refresh the effective per-segment hints (windowed max)."""
        for i, count in entering.items():
            hist = self._hint_hist.get(i)
            if hist is None or hist.maxlen != self.hint_window:
                hist = collections.deque(hist or (), maxlen=self.hint_window)
                self._hint_hist[i] = hist
            hist.append(count)
        self._hints = {
            i: max(hist) for i, hist in self._hint_hist.items() if hist
        }

    def _probe_layers(self) -> dict[int, tuple[int, ...]]:
        """Branch layers a probe step evaluates on top of the plan, keyed
        by segment index: every cfg.branch_layers head lands on the tier
        whose layer range contains it (a branch at a cut probes on the
        upstream tier; the final tier probes its interior branches)."""
        out: dict[int, tuple[int, ...]] = {}
        for i, seg in enumerate(self.segments):
            if seg.is_empty:
                continue
            extra = tuple(sorted(
                b for b in self.cfg.branch_layers
                if seg.layer_lo < b <= seg.layer_hi and b not in seg.branches
            ))
            if extra:
                out[i] = extra
        return out

    def _plan_hops(
        self, batch: int
    ) -> tuple[int | None, dict[int, HopOutcome], tuple[FaultEvent, ...]]:
        """Phase A of the fault plane: health-check every hop the plan
        would cross, in order, *before* any segment dispatches.

        Per hop: circuit-breaker gate (open + cooling -> skip the hop
        entirely, a fast degrade that is NOT a link observation; open +
        cooled -> one half-open probe attempt), then the policy's attempt
        loop against this step's drawn hop condition, with the transfer
        deadline evaluated on the worst-case full-batch payload so the
        decision never depends on the live trajectory.  The first hop
        that fails breaks the chain (later hops are not attempted).

        Returns (broken hop index or None, per-hop outcomes for attempted
        hops, the step's event trace)."""
        pol = self.hop_policy
        model = self.fault_model
        step = self.fault_step
        events: list[FaultEvent] = []
        outcomes: dict[int, HopOutcome] = {}
        broken: int | None = None
        for j in range(self._head_idx):
            br = self._breakers.get(j)
            if br is None:
                br = self._breakers[j] = CircuitBreaker(pol)
            gate = br.gate(step)
            if gate == "skip":
                events.append(FaultEvent(step, j, "breaker_skip"))
                broken = j
                break
            if gate == "probe":
                events.append(FaultEvent(step, j, "breaker_half_open"))
            attempts = 1 if gate == "probe" else 1 + pol.max_retries
            cond, jitter_u, drops = model.draw(step, j, attempts)
            est_bytes = batch * bytes_per_sequence(
                self.cfg, self.segments[j].layer_hi
            )
            out = attempt_hop(
                pol, cond, drops, jitter_u, step=step, hop=j,
                est_bytes=est_bytes,
                uplink_bps=self.segments[j].uplink_bps or 0.0,
                attempts=attempts,
            )
            events.extend(out.events)
            outcomes[j] = out
            self.fault_retries += sum(
                1 for e in out.events if e.kind == "retry"
            )
            was = br.state
            br.record(step, out.ok)
            if br.state != was:
                events.append(FaultEvent(step, j, f"breaker_{br.state}"))
            if not out.ok:
                broken = j
                break
        return broken, outcomes, tuple(events)

    def _run_once(
        self, tok: jax.Array, pos, caches: Any, buckets: dict[int, int],
        probe_map: dict[int, tuple[int, ...]] | None = None,
        exited0: jax.Array | None = None,
        probe_rows: jax.Array | None = None,
        probe_m: int | None = None,
        active_np: np.ndarray | None = None,
        degrade: tuple[int, int] | None = None,
    ) -> tuple:
        """Dispatch all tier segments and perform the single host sync.
        Returns (host dict, caches, entering-survivor counts per segment,
        chosen, logits, alive-after-segment counts, plan-exit mask).
        ``exited0`` seeds the exit mask with the dead slots of a
        continuous-batching step (they compact away downstream exactly
        like early exits).  ``degrade=(seg_idx, layer)`` truncates the
        step at ``seg_idx``, whose fn force-finalizes survivors from the
        exit head at ``layer`` (broken-hop fallback; no head tier runs)."""
        probe_map = probe_map or {}
        cfg = self.cfg
        batch = tok.shape[0]
        posj = jnp.asarray(pos, jnp.int32)
        exited = (
            jnp.zeros((batch,), bool) if exited0 is None else exited0
        )
        chosen = jnp.zeros((batch,), jnp.int32)
        x: jax.Array = tok
        fetch: dict[str, Any] = {}
        logits = None
        last_idx = len(self.segments) if degrade is None else degrade[0] + 1

        for i, seg in enumerate(self.segments):
            if i >= last_idx:
                break
            if seg.is_empty:
                continue
            head = i == self._head_idx
            b = buckets.get(i)
            pr = probe_map.get(i, ())
            deg = (
                degrade[1] if degrade is not None and i == degrade[0]
                else None
            )
            if b is None and not pr and deg is None:
                fn = self._fns[i]
            else:
                # Downstream tiers always run the compact->run->scatter fn
                # in bucketed mode — even at bucket == batch — so exited
                # rows' downstream cache writes are always dropped and KV
                # validity stays a pure function of exits, never of which
                # fn variant a hint happened to select.
                fn = self._segment_fn(
                    seg, head, None if b is None else min(b, batch), probe=pr,
                    probe_m=probe_m if pr else None, degrade=deg,
                )
            if pr and probe_m is not None:
                out = fn(
                    self.params, x, posj, exited, chosen, caches, probe_rows
                )
            else:
                out = fn(self.params, x, posj, exited, chosen, caches)
            caches = out["caches"]
            exited, chosen = out["exited"], out["chosen"]
            if seg.branches:
                fetch[f"take{i}"] = out["take"]
                fetch[f"ents{i}"] = out["ents"]
            if pr:
                fetch[f"ptake{i}"] = out["ptake"]
                fetch[f"pents{i}"] = out["pents"]
                if probe_m is not None:
                    fetch[f"pcover{i}"] = out["pcover"]
            if head:
                logits = out["logits"]
            elif deg is None:
                # A degrade-terminal segment force-finalized every row and
                # emits no handoff hidden state (and the loop breaks next
                # iteration anyway).
                x = out["hidden"]

        fetch["tokens"] = chosen
        fetch["exited"] = exited
        host = jax.device_get(fetch)  # the step's single device->host sync
        self.host_syncs += 1

        # Host-side bookkeeping on the fetched masks (no further syncs):
        # cumulative exits -> survivors entering each segment.  Dead slots
        # are never alive, so they neither ship nor widen buckets.  On a
        # degraded step segments past the truncation have no masks; their
        # counts carry the last executed segment's survivors (the rows the
        # fallback head force-finalized).
        exited_run = (
            np.zeros((batch,), bool) if active_np is None
            else ~np.asarray(active_np, bool)
        )
        alive_after_seg = {}
        for i, seg in enumerate(self.segments):
            for row, _layer in enumerate(seg.branches):
                if f"take{i}" in host:
                    exited_run |= host[f"take{i}"][row]
            alive_after_seg[i] = int(batch - exited_run.sum())
        entering = {
            i: alive_after_seg[i - 1]
            for i in range(1, last_idx)
            if not self.segments[i].is_empty
        }
        return host, caches, entering, chosen, logits, alive_after_seg, \
            exited_run

    def step(
        self, tok: jax.Array, pos, caches: Any, *, active=None
    ) -> tuple[TierStepResult, Any]:
        """One decode step across all tiers: exactly one host sync (plus
        one per rare overflow-retry iteration, see module docstring).

        ``pos`` is the shared step position (lock-step) or a per-sequence
        (B,) vector of absolute positions (continuous batching).
        ``active`` (B,) bool marks live request slots: dead slots enter the
        step pre-exited — the entry tier masks them, downstream tiers
        compact them away, and they never count as survivors or ship."""
        cfg = self.cfg
        batch = tok.shape[0]
        # Snapshot (never alias) the caller's mask: the scheduler mutates
        # its live mask when requests retire, and this result — including
        # its on_step/controller consumers — must keep the mask the step
        # actually ran with.
        active_np = None if active is None else np.array(active, dtype=bool)
        exited0 = None if active_np is None else jnp.asarray(~active_np)
        live = batch if active_np is None else int(active_np.sum())
        # ---- fault plane, phase A: decide hop health before dispatch.
        broken: int | None = None
        outcomes: dict[int, HopOutcome] = {}
        fault_events: tuple[FaultEvent, ...] = ()
        degrade: tuple[int, int] | None = None
        if self.fault_model is not None:
            broken, outcomes, fault_events = self._plan_hops(batch)
            self.fault_step += 1
            if broken is not None:
                self.degraded_steps += 1
                cut = self.segments[broken].layer_hi
                # Deepest exit head at or below the broken hop's cut —
                # including a head sitting exactly at the cut, which the
                # healthy plan discards (Sec. IV-B) but degradation
                # re-enables as the fallback.
                deg_layer = max(
                    (b for b in cfg.branch_layers if b <= cut), default=-1
                )
                if deg_layer >= 1:
                    deg_idx = next(
                        i for i, s in enumerate(self.segments)
                        if not s.is_empty
                        and s.layer_lo < deg_layer <= s.layer_hi
                    )
                    degrade = (deg_idx, deg_layer)
        if broken is not None and degrade is None:
            # No exit head at or below the broken hop: nothing upstream
            # can emit, so dispatch nothing (no sync, no cache-clock
            # advance) — every live row fails this step and the caches
            # are returned untouched.
            self.failed_steps += 1
            failed_mask = (
                np.ones((batch,), bool) if active_np is None
                else active_np.copy()
            )
            sim = ()
            if self.simulate_network:
                # Charge only the pre-flight overhead the attempts burned
                # (no payload ever left the entry tier).
                sim = tuple(
                    outcomes[j].overhead_s if j in outcomes else 0.0
                    for j in range(self._head_idx)
                )
                if self.overlap == "pipelined":
                    self.pipeline_fallbacks += 1
                    self.drain()
                total = sum(sim)
                if total > 0:
                    time.sleep(total)
            result = TierStepResult(
                tokens=np.zeros((batch,), np.int32),
                exited=(
                    np.zeros((batch,), bool) if active_np is None
                    else ~active_np
                ),
                exit_tier=np.full((batch,), -1, np.int32),
                branch_take={},
                branch_entropy={},
                shipped_per_hop=(0,) * self._head_idx,
                bytes_per_hop=(0.0,) * self._head_idx,
                tokens_dev=jnp.zeros((batch,), jnp.int32),
                last_logits=None,
                compaction=tuple(
                    HopCompaction(0, 0) for _ in range(self._head_idx)
                ),
                sim_transfer_s=sim,
                live=live,
                active=active_np,
                degraded=np.zeros((batch,), bool),
                failed=failed_mask,
                fault_events=fault_events,
                degraded_hop=broken,
            )
            return result, caches
        probe_map = self._probe_layers() if self.probe_next else {}
        self.probe_next = False
        probe_rows = None
        probe_m = None
        if probe_map and self.probe_sample_frac < 1.0:
            pool = (
                np.flatnonzero(active_np)
                if active_np is not None and active_np.any()
                else np.arange(batch)
            )
            # Sample size: the configured fraction of the nominal batch,
            # capped at the live pool (no duplicate rows burning head
            # FLOPs at low occupancy) and floored to the bucket ladder so
            # the probe-fn shape set stays bounded as occupancy drifts.
            want = min(
                max(1, math.ceil(self.probe_sample_frac * batch)), len(pool)
            )
            m = max(
                b for b in bucket_ladder(batch) if b <= want
            )
            if m < batch:
                # Deterministic rotation over the live rows: successive
                # probes cycle the pool so every row's entropy gets
                # sampled without an RNG in the hot loop.
                sel = pool[(self._probe_offset + np.arange(m)) % len(pool)]
                self._probe_offset = (self._probe_offset + m) % len(pool)
                probe_rows = jnp.asarray(sel, jnp.int32)
                probe_m = m
        buckets = self._plan_buckets(batch)
        host, new_caches, entering, chosen, logits, alive, exited_plan = \
            self._run_once(
                tok, pos, caches, buckets, probe_map,
                exited0, probe_rows, probe_m, active_np, degrade,
            )
        used = {
            i: min(buckets.get(i, batch), batch) for i in entering
        }
        # Exit-rate spike: true survivors overflowed a planned bucket, so
        # excluded survivors carry garbage.  Re-run the whole step from the
        # entry caches with measured buckets — correctness is never traded
        # for the fast path.  One pass is NOT always enough: an excluded
        # survivor's garbage forward pass can spuriously "exit" at a later
        # segment's branch, undercounting that segment's true survivors,
        # so re-check after every run.  Segments before the earliest
        # overflow have exact counts, so each iteration fixes at least
        # that segment (buckets are merged monotonically non-decreasing)
        # and the loop terminates in <= K runs; a belt-and-braces cap
        # falls back to guaranteed-fit full-batch buckets.
        attempts = 0
        while any(entering[i] > used[i] for i in entering):
            self.overflow_retries += 1
            attempts += 1
            if attempts >= len(self.segments):
                buckets = {i: batch for i in entering}
            else:
                buckets = {
                    i: max(
                        min(buckets.get(i, 1), batch),
                        bucket_for(entering[i], batch),
                    )
                    for i in entering
                }
            host, new_caches, entering, chosen, logits, alive, exited_plan = \
                self._run_once(
                    tok, pos, caches, buckets, probe_map,
                    exited0, probe_rows, probe_m, active_np, degrade,
                )
            used = {i: min(buckets.get(i, batch), batch) for i in entering}
        self._observe_hints(entering)

        # Per-branch attribution from the fetched masks.  Probe branches
        # report would-exit masks/entropies only — they never touch
        # exit_tier (the trajectory is that of a normal step).
        exit_tier = np.full((batch,), -1, np.int32)
        branch_take: dict[int, np.ndarray] = {}
        branch_entropy: dict[int, np.ndarray] = {}
        branch_probe_mask: dict[int, np.ndarray] = {}
        for i, seg in enumerate(self.segments):
            for row, layer in enumerate(seg.branches):
                if f"take{i}" not in host:  # truncated degraded step
                    continue
                mask = host[f"take{i}"][row]
                branch_take[layer] = mask
                branch_entropy[layer] = host[f"ents{i}"][row]
                exit_tier[mask] = i
            for row, layer in enumerate(probe_map.get(i, ())):
                if f"ptake{i}" not in host:
                    continue
                branch_take[layer] = host[f"ptake{i}"][row]
                branch_entropy[layer] = host[f"pents{i}"][row]
                if probe_m is not None:
                    branch_probe_mask[layer] = host[f"pcover{i}"]

        # Degraded rows: exited in the fetch but not through any plan
        # branch — the fallback head force-finalized them.  Their tokens
        # are real (the fallback head's argmax); ``exit_tier`` points at
        # the fallback tier; they are deliberately NOT added to
        # ``branch_take`` so exit-probability estimates see only genuine
        # threshold exits.
        degraded_mask = None
        failed_mask = None
        if broken is not None:
            degraded_mask = np.asarray(host["exited"], bool) & ~exited_plan
            exit_tier[degraded_mask] = degrade[0]
            failed_mask = np.zeros((batch,), bool)

        # Hops: one per cut that still has layers (or the head) downstream.
        # A degraded step truncates at the fallback tier, so hops from it
        # onward carried nothing (phase A burned their retry overhead
        # pre-flight; no payload ever reached the broken link).
        stop_hop = self._head_idx if degrade is None else degrade[0]
        shipped, nbytes, compaction = [], [], []
        for j in range(self._head_idx):
            if j >= stop_hop:
                shipped.append(0)
                nbytes.append(0.0)
                compaction.append(HopCompaction(0, 0))
                continue
            cut = self.segments[j].layer_hi
            alive_j = alive[j]
            shipped.append(alive_j)
            nbytes.append(alive_j * bytes_per_sequence(cfg, cut))
            nxt = next(
                i for i in range(j + 1, len(self.segments))
                if not self.segments[i].is_empty
            )
            compaction.append(HopCompaction(alive_j, used.get(nxt, batch)))

        sim = ()
        if self.simulate_network:
            sim_list = []
            for j, nb in enumerate(nbytes):
                o = outcomes.get(j)
                if o is None:
                    up = self.segments[j].uplink_bps
                    if nb > 0 and (not up or up <= 0.0):
                        # Satellite fix: a dead uplink with bytes queued
                        # used to price the hop at 0 s (a dead link looked
                        # free).  With no fault model to degrade through,
                        # fail loudly instead.
                        raise LinkDownError(
                            f"hop {j} ({self.segments[j].name}) must ship "
                            f"{nb:.0f} bytes but uplink_bps is unset/zero; "
                            "attach a LinkFaultModel to degrade instead"
                        )
                    sim_list.append(transfer_seconds(nb, up))
                else:
                    t = 0.0
                    if o.ok and nb > 0:
                        eff = (
                            (self.segments[j].uplink_bps or 0.0)
                            * o.bandwidth_mult
                        )
                        t = o.latency_s + nb * 8.0 / eff
                    sim_list.append(o.overhead_s + t)
            sim = tuple(sim_list)
            if self.overlap == "pipelined" and attempts == 0 and broken is None:
                self._pipeline_transfers(sim)
            else:
                if self.overlap == "pipelined":
                    # Overflow retry or degraded step: fall back to serial
                    # for this step — drain the pipeline, then pay the
                    # transfers inline.
                    self.pipeline_fallbacks += 1
                    self.drain()
                total = sum(sim)
                if total > 0:
                    time.sleep(total)

        result = TierStepResult(
            tokens=host["tokens"],
            exited=host["exited"],
            exit_tier=exit_tier,
            branch_take=branch_take,
            branch_entropy=branch_entropy,
            shipped_per_hop=tuple(shipped),
            bytes_per_hop=tuple(nbytes),
            tokens_dev=chosen,
            last_logits=logits,
            compaction=tuple(compaction),
            sim_transfer_s=sim,
            live=live,
            active=active_np,
            branch_probe_mask=branch_probe_mask,
            degraded=degraded_mask,
            failed=failed_mask,
            fault_events=fault_events,
            degraded_hop=broken,
        )
        return result, new_caches
