"""K-tier BranchyNet serving (beyond-paper; executes core.multitier plans).

The paper's deployment has one bandwidth cliff; real fleets have several
(device -> edge server -> regional cloud -> core cloud).  The lattice
solver in :mod:`repro.core.multitier` already picks the optimal monotone
layer->tier assignment; this server *executes* it on the unified
:class:`~repro.serving.tiers.TierExecutor` runtime: one jitted segment per
tier, device-resident exit masking, survivors shipped across every hop,
and per-hop byte accounting against each :class:`TierSpec`'s uplink.

With K=2 this is exactly the paper's ``PartitionedServer`` (tests assert
token- and byte-level equivalence).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.multitier import MultiTierPlan, TierSpec, expected_time_multitier
from repro.core.profiler import branch_head_cost
from repro.serving.scheduler import ServesRequests
from repro.serving.tiers import (
    HopCompaction,
    TierExecutor,
    TierStepResult,
    segments_for_cuts,
    transfer_seconds,
)

__all__ = ["MultiTierServer", "MultiTierStepReport"]


@dataclasses.dataclass
class MultiTierStepReport:
    tokens: np.ndarray  # (B,)
    exit_tier: np.ndarray  # (B,) int32: tier of the first exit, -1 = head
    exited: np.ndarray  # (B,) bool
    shipped_per_hop: tuple[int, ...]  # survivors crossing each hop
    bytes_per_hop: tuple[float, ...]
    transfer_s_per_hop: tuple[float, ...]  # bytes * 8 / uplink_bps per hop
    est_latency_s: float | None  # lattice cost model at the installed cuts
    compaction: tuple[HopCompaction, ...] = ()  # per-hop (survivors, bucket)
    branch_take: dict[int, np.ndarray] = dataclasses.field(default_factory=dict)
    sim_transfer_s: tuple[float, ...] = ()  # simulated uplink wall time
    # Cumulative executor health counters (bucket-policy observability).
    overflow_retries: int = 0
    pipeline_fallbacks: int = 0
    #: Live request slots this step decoded (== B under lock-step).
    live: int = 0
    #: The executor's raw result — what the request scheduler consumes.
    tier_result: TierStepResult | None = None
    #: Fault-plane outputs (serving.tiers degraded-step contract): rows
    #: finalized from the fallback head / rows that could not emit, the
    #: step's replayable fault trace, and the broken hop (None = healthy).
    degraded: np.ndarray | None = None
    failed: np.ndarray | None = None
    fault_events: tuple = ()
    degraded_hop: int | None = None


@dataclasses.dataclass
class MultiTierServer(ServesRequests):
    cfg: ModelConfig
    params: Any
    tiers: Sequence[TierSpec]
    cuts: tuple[int, ...]  # layer after which each hop happens (K-1,)
    cost: tuple[np.ndarray, np.ndarray] | None = None  # (t_c, alpha) estimates
    compaction: str = "bucketed"  # "off" = legacy masked full-batch tiers
    simulate_network: bool = False  # sleep each hop's transfer time
    overlap: str = "serial"  # "pipelined" = overlap transfers with compute
    use_kernels: bool | None = None  # Pallas decode path; None = cfg/auto
    # Batched exit heads (serving.tiers "Batched exit heads"): one
    # (K, B, D) projection + one multi-head fused entropy-exit launch per
    # tier instead of K head evaluations; bitwise identical either way.
    # The same knob selects the branch-head pricing mode when
    # ``price_heads`` adds the head term to est_latency_s.
    heads_batched: bool = True
    price_heads: bool = False  # opt-in branch-head term in est_latency_s
    hint_window: int = 8  # windowed-max bucket hints (1 = last step only)
    bucket_headroom: float = 0.0  # fractional bucket padding vs retries
    slots: int = 8  # request-scheduler KV slots (submit/run/drain API)
    context_len: int = 4096  # scheduler cache capacity per slot
    # Device mesh (+ optional explicit ShardingPolicy): segments run SPMD
    # (serving.tiers "Mesh-sharded tier segments").  Which tier is priced
    # as sharded lives in each TierSpec's ``devices``/``ici_bps``, carried
    # into the segment specs and the lattice estimator.
    mesh: Any = None
    sharding: Any = None
    # Fault plane (serving.faults): a seeded LinkFaultModel arms hop
    # fault injection + breaker-gated retries + exit-head degradation;
    # hop_policy overrides the retry/timeout/breaker defaults.
    fault_model: Any = None
    hop_policy: Any = None

    def __post_init__(self):
        self.tiers = tuple(self.tiers)
        self.cuts = tuple(int(c) for c in self.cuts)
        if len(self.cuts) != len(self.tiers) - 1:
            raise ValueError(
                f"{len(self.tiers)} tiers need {len(self.tiers) - 1} cuts, "
                f"got {self.cuts}"
            )
        self.executor = TierExecutor(
            self.cfg, self.params, self._segments(self.cuts),
            compaction=self.compaction,
            simulate_network=self.simulate_network,
            overlap=self.overlap,
            use_kernels=self.use_kernels,
            batched_heads=self.heads_batched,
            hint_window=self.hint_window,
            bucket_headroom=self.bucket_headroom,
            mesh=self.mesh,
            sharding=self.sharding,
            fault_model=self.fault_model,
            hop_policy=self.hop_policy,
        )
        self.params = self.executor.params

    @classmethod
    def from_plan(
        cls,
        cfg: ModelConfig,
        params: Any,
        plan: MultiTierPlan,
        tiers: Sequence[TierSpec],
        cost: tuple[np.ndarray, np.ndarray] | None = None,
    ) -> "MultiTierServer":
        return cls(cfg, params, tiers, plan.cut_after, cost)

    def _segments(self, cuts: tuple[int, ...]):
        return segments_for_cuts(
            self.cfg, cuts,
            names=tuple(t.name for t in self.tiers),
            uplinks=tuple(t.uplink_bps for t in self.tiers),
            devices=tuple(t.devices for t in self.tiers),
        )

    def install_cuts(self, cuts: Sequence[int]) -> None:
        """Hot-swap the hop points; unchanged tier segments keep their
        compiled functions (no re-jit)."""
        cuts = tuple(int(c) for c in cuts)
        if len(cuts) != len(self.tiers) - 1:
            raise ValueError(
                f"{len(self.tiers)} tiers need {len(self.tiers) - 1} cuts, "
                f"got {cuts}"
            )
        if cuts == self.cuts:
            return
        self.executor.install(self._segments(cuts))
        self.cuts = cuts

    # ------------------------------------------------------------------
    def step(
        self, tok: jax.Array, pos, caches: Any, *, active=None
    ) -> tuple[MultiTierStepReport, Any]:
        res, caches = self.executor.step(tok, pos, caches, active=active)
        # A hop whose bandwidth was never set (TierSpec.uplink_bps defaults
        # to 0.0) reports 0.0 transfer time, matching the executor's
        # sim_transfer_s accounting, instead of dividing by zero.
        transfer = tuple(
            transfer_seconds(nb, self.tiers[j].uplink_bps)
            for j, nb in enumerate(res.bytes_per_hop)
        )
        rep = MultiTierStepReport(
            tokens=res.tokens,
            exit_tier=res.exit_tier,
            exited=res.exited,
            shipped_per_hop=res.shipped_per_hop,
            bytes_per_hop=res.bytes_per_hop,
            transfer_s_per_hop=transfer,
            est_latency_s=self._estimate(res),
            compaction=res.compaction,
            branch_take=res.branch_take,
            sim_transfer_s=res.sim_transfer_s,
            overflow_retries=self.executor.overflow_retries,
            pipeline_fallbacks=self.executor.pipeline_fallbacks,
            live=res.live,
            tier_result=res,
            degraded=res.degraded,
            failed=res.failed,
            fault_events=res.fault_events,
            degraded_hop=res.degraded_hop,
        )
        return rep, caches

    def _estimate(self, res) -> float | None:
        """Lattice cost model (core.multitier) at the installed cuts with
        the *measured* per-branch exit fractions substituted for p.  When
        the runtime compacts, the estimate uses the bucketed cost so it is
        honest about padding waste; when it pipelines, the overlap cost so
        it reports the steady-state bottleneck stage.  The step's live
        width feeds the occupancy term under continuous batching."""
        if self.cost is None:
            return None
        t_c, alpha = self.cost
        p = np.zeros(len(t_c))
        batch = res.tokens.shape[0]
        live = getattr(res, "live", 0) or batch
        alive = float(live)
        for layer in sorted(res.branch_take):
            took = float(res.branch_take[layer].sum())
            p[layer] = took / alive if alive > 0 else 0.0
            alive -= took
        bucketed = self.compaction == "bucketed"
        head_cost = (
            branch_head_cost(self.cfg, batch, heads_batched=self.heads_batched)
            if self.price_heads else None
        )
        return expected_time_multitier(
            t_c, alpha, p, list(self.tiers), self.cuts,
            batch=batch if bucketed else None,
            overlap=self.overlap == "pipelined",
            occupancy=live / batch if bucketed else None,
            head_cost=head_cost,
            branch_layers=self.cfg.branch_layers,
        )
