"""Fault injection and hop-failure policy for the tier runtime.

The paper's premise is that the optimal cut depends on live network
bandwidth — which means the runtime has to survive the network
*changing underneath it*.  This module supplies the two halves of that
story:

  * `LinkFaultModel` — a deterministic, seeded fault injector for the
    simulated hops: per-hop bandwidth multipliers, latency spikes, drop
    probability, and scripted flap windows (hop hard-down for a step
    range).  Every draw is keyed by ``(seed, step, hop)`` so the same
    schedule replays bit-identically regardless of execution order,
    retry count, or how many hops a step actually exercises.
  * `HopPolicy` / `CircuitBreaker` — what the sender *does* about a bad
    hop: a per-attempt timeout, bounded retries with exponential backoff
    (+ optional seeded jitter), and a per-hop circuit breaker
    (closed → open after N consecutive failures, half-open single probe
    after a cooldown, closed again on probe success).

The executor consults these **before dispatch** (phase A of its fault
plane): hop health for a step is decided host-side from the worst-case
payload, so the decision is independent of the batch's live trajectory
and never costs an extra device sync.  `attempt_hop` below is that
pure decision function; it returns the outcome, the wall-clock overhead
the failed attempts would have burned, and a replayable event trace.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping

import numpy as np

__all__ = [
    "LinkDownError",
    "FlapWindow",
    "HopCondition",
    "HEALTHY",
    "FaultEvent",
    "LinkFaultModel",
    "HopPolicy",
    "CircuitBreaker",
    "HopOutcome",
    "attempt_hop",
]


class LinkDownError(RuntimeError):
    """A wall-clock simulated hop must ship bytes but has no usable
    uplink and no fault model to degrade through.

    Raised by `TierExecutor.step` when ``simulate_network=True``, the
    hop's ``uplink_bps`` is unset/zero, bytes are queued on it, and no
    `LinkFaultModel` is attached (with one attached the step degrades
    instead).  Previously the hop was silently priced at zero seconds —
    a dead link looked *free*."""


@dataclasses.dataclass(frozen=True)
class FlapWindow:
    """Scripted hard-down window: ``hop`` is dead for steps in
    ``[start_step, end_step)`` (executor fault-step clock)."""

    hop: int
    start_step: int
    end_step: int

    def covers(self, step: int, hop: int) -> bool:
        return hop == self.hop and self.start_step <= step < self.end_step


@dataclasses.dataclass(frozen=True)
class HopCondition:
    """The sampled state of one hop at one step."""

    bandwidth_mult: float = 1.0  # effective bw = uplink_bps * mult
    latency_s: float = 0.0  # additive spike on a successful transfer
    flapped: bool = False  # scripted hard-down (flap window)


HEALTHY = HopCondition()


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One replayable entry in a step's fault trace.

    kinds: ``link_down`` / ``drop`` / ``timeout`` (failed attempts),
    ``retry`` (backoff before attempt N), ``exhausted`` (all attempts
    failed), ``breaker_open`` / ``breaker_half_open`` / ``breaker_closed``
    (state transitions), ``breaker_skip`` (open breaker short-circuited
    the hop without attempting it — *not* a link observation)."""

    step: int
    hop: int
    kind: str
    attempt: int = -1
    detail: float = 0.0


def _per_hop(value, hop: int, default: float) -> float:
    if isinstance(value, Mapping):
        return float(value.get(hop, default))
    return float(value)


@dataclasses.dataclass(frozen=True)
class LinkFaultModel:
    """Deterministic seeded fault injector.

    Each scalar knob also accepts a ``{hop: value}`` mapping (hops not
    listed get the healthy default).  ``draw(step, hop, attempts)``
    samples the hop condition plus per-attempt drop flags and a backoff
    jitter uniform from ``default_rng((seed, step, hop))`` — the PCG64
    stream is prefix-stable, so outcomes are identical across runs and
    independent of how many attempts the policy allows.
    """

    seed: int = 0
    drop_p: float | Mapping[int, float] = 0.0
    bandwidth_mult: float | Mapping[int, float] = 1.0
    spike_p: float | Mapping[int, float] = 0.0
    spike_s: float | Mapping[int, float] = 0.0
    flaps: tuple[FlapWindow, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "flaps", tuple(self.flaps))

    def flapped(self, step: int, hop: int) -> bool:
        return any(w.covers(step, hop) for w in self.flaps)

    def condition(self, step: int, hop: int) -> HopCondition:
        cond, _, _ = self.draw(step, hop, 0)
        return cond

    def draw(
        self, step: int, hop: int, attempts: int
    ) -> tuple[HopCondition, float, np.ndarray]:
        """-> (condition, backoff-jitter uniform, per-attempt drop flags)."""
        rng = np.random.default_rng((int(self.seed), int(step), int(hop)))
        u = rng.random(2 + attempts)
        spiked = u[0] < _per_hop(self.spike_p, hop, 0.0)
        cond = HopCondition(
            bandwidth_mult=_per_hop(self.bandwidth_mult, hop, 1.0),
            latency_s=_per_hop(self.spike_s, hop, 0.0) if spiked else 0.0,
            flapped=self.flapped(step, hop),
        )
        drops = u[2:] < _per_hop(self.drop_p, hop, 0.0)
        return cond, float(u[1]), drops


@dataclasses.dataclass(frozen=True)
class HopPolicy:
    """Per-hop failure policy: attempt timeout, bounded retries with
    exponential backoff (+ jitter), and circuit-breaker thresholds.

    ``timeout_s`` is an admission-control deadline evaluated against the
    *worst-case full-batch payload* (host-side, pre-dispatch), so the
    pass/fail decision is deterministic and trajectory-independent."""

    timeout_s: float = 1.0
    max_retries: int = 2
    backoff_s: float = 0.05
    backoff_mult: float = 2.0
    jitter_frac: float = 0.0
    breaker_threshold: int = 3
    breaker_cooldown_steps: int = 4

    def backoff(self, attempt: int, jitter_u: float = 0.0) -> float:
        """Backoff slept before retry ``attempt`` (1-based)."""
        base = self.backoff_s * self.backoff_mult ** (attempt - 1)
        return base * (1.0 + self.jitter_frac * jitter_u)


class CircuitBreaker:
    """Per-hop breaker: closed → open after ``breaker_threshold``
    consecutive failures; after ``breaker_cooldown_steps`` an open
    breaker admits a single half-open probe (no retries); probe success
    closes it, probe failure re-opens and restarts the cooldown."""

    def __init__(self, policy: HopPolicy):
        self.policy = policy
        self.state = "closed"
        self.failures = 0
        self._opened_step = -1
        self.transitions: list[tuple[int, str]] = []

    def _set(self, step: int, state: str) -> None:
        self.state = state
        self.transitions.append((int(step), state))

    def gate(self, step: int) -> str:
        """-> ``attempt`` (normal), ``probe`` (half-open, single try), or
        ``skip`` (open, cooling down: degrade without touching the link)."""
        if self.state == "open":
            if step - self._opened_step >= self.policy.breaker_cooldown_steps:
                self._set(step, "half_open")
                return "probe"
            return "skip"
        if self.state == "half_open":
            return "probe"
        return "attempt"

    def record(self, step: int, ok: bool) -> None:
        if ok:
            self.failures = 0
            if self.state != "closed":
                self._set(step, "closed")
            return
        self.failures += 1
        if self.state == "half_open" or self.failures >= self.policy.breaker_threshold:
            if self.state != "open":
                self._set(step, "open")
            self._opened_step = step


@dataclasses.dataclass(frozen=True)
class HopOutcome:
    """Result of phase-A hop planning for one hop at one step."""

    ok: bool
    attempts: int  # attempts actually made
    overhead_s: float  # backoffs + failed-attempt timeouts (wall-clock)
    bandwidth_mult: float  # applies to the successful transfer, if any
    latency_s: float  # additive spike on the successful transfer
    events: tuple[FaultEvent, ...] = ()


def attempt_hop(
    policy: HopPolicy,
    cond: HopCondition,
    drops: Iterable[bool],
    jitter_u: float,
    *,
    step: int,
    hop: int,
    est_bytes: float,
    uplink_bps: float,
    attempts: int,
) -> HopOutcome:
    """Pure phase-A attempt loop for one hop.

    Each attempt fails on: hard-down link (flap or zero effective
    bandwidth), a sampled drop, or the estimated transfer exceeding
    ``policy.timeout_s``.  Failed attempts charge the timeout; retries
    charge their backoff.  Nothing here touches devices or the clock —
    the caller decides what to do with ``overhead_s``."""
    drops = np.asarray(list(drops), dtype=bool)
    events: list[FaultEvent] = []
    overhead = 0.0
    eff_bps = max(float(uplink_bps or 0.0), 0.0) * cond.bandwidth_mult
    down = cond.flapped or eff_bps <= 0.0
    ok = False
    made = 0
    for a in range(attempts):
        made = a + 1
        if a > 0:
            b = policy.backoff(a, jitter_u)
            overhead += b
            events.append(FaultEvent(step, hop, "retry", a, b))
        if down:
            overhead += policy.timeout_s
            events.append(FaultEvent(step, hop, "link_down", a, policy.timeout_s))
            continue
        if a < len(drops) and drops[a]:
            overhead += policy.timeout_s
            events.append(FaultEvent(step, hop, "drop", a, policy.timeout_s))
            continue
        est_s = cond.latency_s + est_bytes * 8.0 / eff_bps
        if est_s > policy.timeout_s:
            overhead += policy.timeout_s
            events.append(FaultEvent(step, hop, "timeout", a, est_s))
            continue
        ok = True
        break
    if not ok:
        events.append(FaultEvent(step, hop, "exhausted", made - 1, overhead))
    return HopOutcome(
        ok=ok,
        attempts=made,
        overhead_s=overhead,
        bandwidth_mult=cond.bandwidth_mult,
        latency_s=cond.latency_s,
        events=tuple(events),
    )
