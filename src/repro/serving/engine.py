"""Batched serving engine with BranchyNet early exits.

The engine owns the jitted prefill closure and a single-tier
:class:`~repro.serving.tiers.TierExecutor` (the K=1 configuration of the
unified runtime: one segment spanning the whole trunk, every side branch
evaluated in place).  It tracks positions and records per-branch exit
statistics — the live measurement that calibrates the partitioner's
``p_k`` (paper Sec. IV-C: "the probability that a sample is classified at
the side branch" is an input-data property, so a serving system must
estimate it online).

Exit masking runs device-resident inside the fused decode step; the loop
performs one host sync per decoded token (down from 3 per branch).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.calibration import calibrate_exit_probs
from repro.launch.mesh import mesh_devices
from repro.models import model as M
from repro.serving.scheduler import ServesRequests
from repro.serving.tiers import TierExecutor, TierStepResult, segments_for_cuts

__all__ = ["ServingEngine", "ExitStats"]


@dataclasses.dataclass
class ExitStats:
    """Counts of first-exit events per branch across decoded tokens."""

    branch_layers: tuple[int, ...]
    counts: np.ndarray  # (K+1,): per branch + the main head
    entropies: list[np.ndarray]  # per step: (K, B) normalized entropies

    @property
    def total(self) -> int:
        return int(self.counts.sum())

    def exit_fractions(self) -> np.ndarray:
        return self.counts / max(self.total, 1)

    def conditional_probs(self) -> np.ndarray:
        """Sequential conditional p_k (what CostProfile consumes)."""
        alive = float(self.total)
        out = []
        for c in self.counts[:-1]:
            out.append(float(c) / alive if alive > 0 else 0.0)
            alive -= float(c)
        return np.asarray(out)

    def calibrate(self, threshold: float):
        ents = np.concatenate(self.entropies, axis=1)  # (K, steps*B)
        return calibrate_exit_probs(ents, threshold)


@dataclasses.dataclass
class ServingEngine(ServesRequests):
    cfg: ModelConfig
    params: Any
    context_len: int = 4096
    # Decode hot path on the Pallas kernels; None = cfg.use_kernels
    # (still None = auto: kernels on TPU, jnp elsewhere).
    use_kernels: bool | None = None
    # Batched exit heads: one stacked projection + one multi-head fused
    # entropy-exit launch per step (serving.tiers "Batched exit heads").
    heads_batched: bool = True
    # Request-scheduler KV slots for the submit()/run()/drain() API.
    slots: int = 8
    # Device mesh (+ optional explicit ShardingPolicy): run the trunk
    # tensor/expert-parallel — see serving.tiers "Mesh-sharded tier
    # segments".  Params/caches are placed by the executor.
    mesh: Any = None
    sharding: Any = None

    def __post_init__(self):
        cfg = self.cfg
        self._exec = TierExecutor(
            cfg, self.params,
            segments_for_cuts(
                cfg, (), devices=(mesh_devices(self.mesh),) if self.mesh else None
            ),
            use_kernels=self.use_kernels,
            batched_heads=self.heads_batched,
            mesh=self.mesh, sharding=self.sharding,
        )
        # The executor owns the (possibly mesh-placed) param tree; prefill
        # must run on the same placement.
        self.params = self._exec.params
        self._prefill = self._exec._jit(
            lambda params, inputs, caches: M.prefill(params, inputs, cfg, caches)
        )

    @property
    def executor(self) -> TierExecutor:
        return self._exec

    def step(
        self, tok: jax.Array, pos, caches: Any, *, active=None
    ) -> tuple[TierStepResult, Any]:
        """One fused decode step (the K=1 tier configuration); ``pos`` may
        be per-sequence and ``active`` masks dead request slots — the
        entry points the request scheduler drives."""
        return self._exec.step(tok, pos, caches, active=active)

    def start(self, inputs: dict) -> dict:
        """Prefill a batch of prompts; returns mutable serve state."""
        batch = inputs["tokens"].shape[0]
        prompt_len = inputs["tokens"].shape[1]
        if self.cfg.frontend == "vision":
            prompt_len += self.cfg.num_patches
        caches = self._exec.shard_caches(
            M.init_caches(self.cfg, batch, self.context_len)
        )
        logits, caches = self._prefill(self.params, inputs, caches)
        return {
            "caches": caches,
            "pos": prompt_len,
            "last_logits": logits[:, 0],
            "batch": batch,
        }

    def decode(
        self, state: dict, steps: int, *, greedy: bool = True, key=None
    ) -> tuple[np.ndarray, ExitStats]:
        """Decode ``steps`` tokens; returns (tokens (B, steps), exit stats).

        A sequence "exits" at the first branch whose normalized entropy
        clears cfg.exit_threshold; its emitted token comes from that branch
        head (BranchyNet inference, paper Sec. III).
        """
        cfg = self.cfg
        k = len(cfg.branch_layers)
        batch = state["batch"]
        counts = np.zeros(k + 1, dtype=np.int64)
        ents_log: list[np.ndarray] = []
        toks_out = []

        tok = jnp.argmax(state["last_logits"], -1).astype(jnp.int32)[:, None]
        caches = state["caches"]
        pos = state["pos"]
        for _ in range(steps):
            res, caches = self._exec.step(tok, pos, caches)
            pos += 1
            for j, layer in enumerate(cfg.branch_layers):
                counts[j] += int(res.branch_take[layer].sum())
            counts[k] += int((~res.exited).sum())
            ents_log.append(
                np.stack([res.branch_entropy[l] for l in cfg.branch_layers])
                if k else np.zeros((0, batch))
            )
            toks_out.append(res.tokens)
            tok = res.tokens_dev[:, None]

        state["caches"] = caches
        state["pos"] = pos
        state["last_logits"] = res.last_logits
        return np.stack(toks_out, axis=1), ExitStats(
            cfg.branch_layers, counts, ents_log
        )

    @property
    def host_syncs(self) -> int:
        """Device->host syncs performed by decode steps so far."""
        return self._exec.host_syncs
