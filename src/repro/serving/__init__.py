"""repro.serving — BranchyNet serving on the unified K-tier runtime.

    TierExecutor / TierSegment   device-resident exit/transfer core
    ServingEngine                K=1 (monolithic, calibration source)
    PartitionedServer            K=2 (the paper's edge/cloud system)
    MultiTierServer              K>=3 (lattice plans from core.multitier)
    RepartitionController        live p_k -> solver -> hot swap
    RequestScheduler             continuous-batching request lifecycle
                                 (submit/run/drain over recycled KV slots)
    LinkFaultModel / HopPolicy   seeded hop fault injection + retry/breaker
                                 policy (degraded steps, edge fallback)
"""

from repro.serving.controller import RepartitionController
from repro.serving.engine import ExitStats, ServingEngine
from repro.serving.faults import (
    CircuitBreaker,
    FaultEvent,
    FlapWindow,
    HopPolicy,
    LinkDownError,
    LinkFaultModel,
)
from repro.serving.multitier import MultiTierServer, MultiTierStepReport
from repro.serving.partitioned import PartitionedServer, StepReport
from repro.serving.scheduler import (
    Request,
    RequestResult,
    RequestScheduler,
    SchedulerStepReport,
)
from repro.serving.tiers import (
    HopCompaction,
    TierExecutor,
    TierSegment,
    TierStepResult,
    bytes_per_sequence,
    segments_for_cuts,
)

__all__ = [
    "ExitStats",
    "ServingEngine",
    "PartitionedServer",
    "StepReport",
    "MultiTierServer",
    "MultiTierStepReport",
    "RepartitionController",
    "Request",
    "RequestResult",
    "RequestScheduler",
    "SchedulerStepReport",
    "HopCompaction",
    "TierExecutor",
    "TierSegment",
    "TierStepResult",
    "bytes_per_sequence",
    "segments_for_cuts",
    "CircuitBreaker",
    "FaultEvent",
    "FlapWindow",
    "HopPolicy",
    "LinkDownError",
    "LinkFaultModel",
]
