"""repro.serving — see module docstrings."""
