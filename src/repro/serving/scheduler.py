"""Continuous-batching request scheduler: the serving stack's request
lifecycle over the unified K-tier runtime.

The paper picks a partition point per *deployment*, but its value is
realized per *request*: a real edge/cloud serving system faces a stream
of arrivals, not one synchronized batch (cf. Parthasarathy & Rupprecht
2022 on throughput-maximizing DNN partitioning, and Li et al.'s
on-demand edge/cloud co-inference).  The lock-step loop this repo served
with until now decodes a fixed batch in unison — a request that needs 3
tokens while its neighbor needs 30 pins a dead KV slot for 27 steps, so
measured throughput badly understates what BranchyNet partitioning buys.

:class:`RequestScheduler` replaces the lock-step batch with a request
lifecycle over ``slots`` full-batch-resident KV rows:

    submit()  -> admission queue (prompt, max_new_tokens, arrival)
    admit     -> :meth:`TierExecutor.prefill_rows` prefills waiting
                 prompts *into freed cache rows* between decode steps —
                 per-sequence slot validity (``pos: (B, C)``) and the
                 ``rows`` plumbing make a recycled slot safe to overwrite
                 in place, so no cache reshape or re-jit ever happens
    step      -> one fused decode step over the live slots
                 (``TierExecutor.step(pos=(B,), active=...)``): each
                 request decodes at its own absolute position; dead slots
                 enter pre-exited and compact away downstream, so the
                 bucket ladder tracks *live occupancy*
    retire    -> a request leaves when its token budget is spent (or, for
                 classification-style traffic, at its first early exit
                 with ``stop_on_exit=True``); its slot is immediately
                 reusable

The scheduler preserves the runtime's two contracts:

  * **one device->host sync per decode step** — admission prefill keeps
    everything device-resident (the first input token is an argmax of the
    prefill logits on device) and retirement bookkeeping reads only the
    step's already-fetched masks;
  * **trajectory isolation** — each request's token/exit trajectory is
    bitwise identical to running it alone from its admission state,
    independent of which slot it recycled, who occupied it before, or
    who shares the batch with it.

Admission policy: ``policy="continuous"`` (the point of this module)
fills any free slot as soon as a queued request's arrival step has
passed; ``policy="gang"`` only admits when *all* slots are free — the
lock-step degenerate case, kept as the benchmark baseline.

Per-request accounting: TTFT (arrival -> first decoded token on host)
and end-to-end latency land in :class:`RequestResult`; per-step
admissions/retirements/occupancy land in :class:`SchedulerStepReport`.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.multitier import bucket_for

__all__ = [
    "Request",
    "RequestResult",
    "RequestScheduler",
    "SchedulerStepReport",
    "ServesRequests",
]


@dataclasses.dataclass
class Request:
    """One unit of serving work: a prompt and a decode budget."""

    prompt: np.ndarray  # (P,) int32 token ids
    max_new_tokens: int
    rid: int = -1  # assigned by submit()
    #: Retire at the first token that early-exits at a side branch (the
    #: paper's classification semantics: the answer is ready).  False
    #: decodes the full budget; exits then only make tokens cheaper.
    stop_on_exit: bool = False
    #: Earliest decode-step index admission may happen (simulated arrival
    #: for reproducible workloads; 0 = admissible immediately).
    arrival_step: int = 0
    #: Wall clock when the request became admissible: submit() time, or —
    #: for a simulated future ``arrival_step`` — the moment the step
    #: clock reaches it (so TTFT/latency measure queueing + serving, not
    #: pre-arrival simulation time).
    arrival_s: float = 0.0
    _arrived: bool = True  # arrival_s already stamped
    #: Times this request was re-queued after a slot fault (capped by the
    #: scheduler's ``max_requeues``; exceeded -> terminal ``failed``).
    _requeues: int = 0


@dataclasses.dataclass
class RequestResult:
    """Everything known about a finished (or in-flight) request."""

    rid: int
    prompt_len: int
    tokens: list[int]  # decoded token ids, in order
    exit_tiers: list[int]  # per token: tier of the first exit, -1 = head
    exited: list[bool]  # per token: did it early-exit at a branch
    slot: int = -1  # KV row it was served in
    admitted_step: int = -1
    retired_step: int = -1
    ttft_s: float | None = None  # arrival -> first decoded token on host
    latency_s: float | None = None  # arrival -> retirement
    done: bool = False
    #: Terminal status: "ok" — every token came from the planned
    #: trajectory; "degraded" — finished, but >= 1 token was finalized
    #: from a fallback exit head below a broken hop (see
    #: ``degraded_tokens``); "failed" — an unrecoverable hop fault ended
    #: the request with no token that step and requeues were exhausted
    #: (or disabled); "requeued" — transient marker on a result whose
    #: request went back in the queue (replaced at re-admission).
    status: str = "ok"
    #: Tokens in ``tokens`` that a degraded step force-finalized from a
    #: fallback head (real tokens, shallower than planned).
    degraded_tokens: int = 0


@dataclasses.dataclass
class SchedulerStepReport:
    """One decode step of the request loop (host-side bookkeeping only —
    everything here derives from the step's single fetched sync)."""

    step: int
    live: int  # occupied slots the step decoded
    admitted: tuple[int, ...]  # rids admitted (prefilled) before the step
    retired: tuple[int, ...]  # rids retired after the step
    emitted: dict[int, int]  # rid -> token decoded this step
    occupancy: float = 0.0  # live / slots
    server_report: Any = None  # the underlying server/tier step report
    #: rids whose token this step came from a fallback exit head
    #: (degraded step) and rids whose slot hit an unrecoverable fault
    #: (retired failed, or re-queued when ``requeue_on_fail``).
    degraded: tuple[int, ...] = ()
    failed: tuple[int, ...] = ()


class RequestScheduler:
    """Admission queue + slot allocator + decode loop over a tier server.

    ``server`` is any of :class:`~repro.serving.engine.ServingEngine`,
    :class:`~repro.serving.partitioned.PartitionedServer`,
    :class:`~repro.serving.multitier.MultiTierServer` — anything exposing
    ``cfg``, ``executor`` and ``step(tok, pos, caches, active=...)``.
    Servers construct one lazily behind ``submit()/run()/drain()``; build
    it directly to control ``slots``/``context_len``/``policy``.

    ``on_step`` callbacks (e.g. ``RepartitionController.observe``) fire
    after every decode step with the underlying tier step result, so drift
    detection and epsilon probes ride the continuous loop unchanged.
    """

    def __init__(
        self,
        server: Any,
        slots: int,
        context_len: int,
        *,
        policy: str = "continuous",
        reset_on_retire: bool = False,
        clock: Callable[[], float] = time.perf_counter,
        on_step: Sequence[Callable[[Any], Any]] = (),
        requeue_on_fail: bool = False,
        max_requeues: int = 1,
    ):
        if policy not in ("continuous", "gang"):
            raise ValueError(f"unknown admission policy: {policy!r}")
        if slots < 1:
            raise ValueError(f"need at least one slot, got {slots}")
        cfg = server.cfg
        if cfg.frontend != "none" or cfg.arch_type == "audio":
            raise NotImplementedError(
                "request scheduling covers text-frontend trunks (vision "
                "patch embeds / audio encoder states are per-batch, not "
                "per-slot)"
            )
        from repro.models import model as M  # serving <-> models layering

        self.server = server
        self.executor = server.executor
        self.cfg = cfg
        self.slots = slots
        self.context_len = context_len
        self.policy = policy
        self.reset_on_retire = reset_on_retire
        self.clock = clock
        self.on_step = list(on_step)
        #: A request whose slot hits an unrecoverable fault (its row is in
        #: the step result's ``failed`` mask) re-enters the queue head for
        #: a fresh admission instead of retiring ``failed`` — up to
        #: ``max_requeues`` times per request.  Its slot is reclaimed
        #: either way (the allocator invariant the fault tests pin).
        self.requeue_on_fail = requeue_on_fail
        self.max_requeues = max_requeues

        # Mesh-sharded executors place the slot caches under the policy's
        # cache rules up front (no-op otherwise); admission prefill and
        # decode steps then keep the layouts through propagation.
        self.caches = self.executor.shard_caches(
            M.init_caches(cfg, slots, context_len)
        )
        self.pos = np.zeros(slots, np.int32)  # next decode position per slot
        self.active = np.zeros(slots, bool)
        self.tok_dev = jnp.zeros((slots, 1), jnp.int32)
        self.queue: collections.deque[Request] = collections.deque()
        self.step_count = 0  # scheduler clock (idle arrival ticks included)
        self.decode_steps = 0  # steps that actually decoded (1 sync each)
        self._next_rid = 0
        self._slot_req: list[Request | None] = [None] * slots
        self._remaining = np.zeros(slots, np.int64)
        self.results: dict[int, RequestResult] = {}
        #: Completed-request rids in retirement order.
        self.finished: list[int] = []
        self.total_tokens = 0  # useful tokens decoded for live requests

    # ------------------------------------------------------------ submit
    def submit(
        self,
        prompt,
        max_new_tokens: int,
        *,
        stop_on_exit: bool = False,
        arrival_step: int = 0,
    ) -> int:
        """Queue one request; returns its rid.  Admission happens between
        decode steps, as soon as a slot frees up (policy="continuous")."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) < 1:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {max_new_tokens}"
            )
        if len(prompt) + max_new_tokens > self.context_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + budget ({max_new_tokens}) "
                f"exceeds context_len {self.context_len}"
            )
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(
            prompt=prompt,
            max_new_tokens=int(max_new_tokens),
            rid=rid,
            stop_on_exit=stop_on_exit,
            arrival_step=int(arrival_step),
            arrival_s=self.clock(),
            _arrived=int(arrival_step) <= self.step_count,
        ))
        return rid

    # --------------------------------------------------------- admission
    def _free_slots(self) -> list[int]:
        return [s for s in range(self.slots) if not self.active[s]]

    def _mark_arrivals(self) -> None:
        """Stamp arrival_s the moment a simulated future arrival becomes
        admissible, so TTFT/latency measure queueing + serving rather than
        pre-arrival simulation time."""
        now = None
        for req in self.queue:
            if not req._arrived and req.arrival_step <= self.step_count:
                now = self.clock() if now is None else now
                req.arrival_s = now
                req._arrived = True

    def _admit(self) -> tuple[int, ...]:
        """Prefill queued requests into freed rows (between decode steps).
        Admission is FIFO among *arrived* requests — a queue head whose
        simulated arrival is still in the future never blocks a later
        submit that has already arrived.  Same-length prompts group into
        one prefill call, padded up the bucket ladder with OOB sentinel
        rows so (P, n) jit shapes recur."""
        free = self._free_slots()
        if self.policy == "gang" and len(free) < self.slots:
            return ()
        ready: list[Request] = []
        if free and self.queue:
            waiting: collections.deque[Request] = collections.deque()
            for req in self.queue:
                if len(ready) < len(free) and req._arrived:
                    ready.append(req)
                else:
                    waiting.append(req)
            self.queue = waiting
        if not ready:
            return ()
        admitted = []
        by_len: dict[int, list[Request]] = {}
        for req in ready:
            by_len.setdefault(len(req.prompt), []).append(req)
        for plen, group in by_len.items():
            rows = [free.pop(0) for _ in group]
            n = bucket_for(len(group), self.slots)
            toks = np.zeros((n, plen), np.int32)
            row_ids = np.full(n, self.slots, np.int32)  # OOB sentinel pad
            for i, req in enumerate(group):
                toks[i] = req.prompt
                row_ids[i] = rows[i]
            self.caches, tok0 = self.executor.prefill_rows(
                self.caches, toks, row_ids
            )
            # First decode input = argmax of the prefill logits, straight
            # from device to the token buffer — no host sync at admission.
            self.tok_dev = self.tok_dev.at[
                jnp.asarray(rows, jnp.int32), 0
            ].set(tok0[: len(group)])
            for slot, req in zip(rows, group):
                self.active[slot] = True
                self.pos[slot] = plen
                self._remaining[slot] = req.max_new_tokens
                self._slot_req[slot] = req
                self.results[req.rid] = RequestResult(
                    rid=req.rid,
                    prompt_len=plen,
                    tokens=[],
                    exit_tiers=[],
                    exited=[],
                    slot=slot,
                    admitted_step=self.step_count,
                )
                admitted.append(req.rid)
        return tuple(admitted)

    # -------------------------------------------------------------- step
    def step(self) -> SchedulerStepReport | None:
        """Admit into freed rows, then run one decode step over the live
        slots.  Returns None when there is nothing to do (idle step: no
        live request and nothing admissible yet advances the step clock,
        so simulated arrivals keyed on ``arrival_step`` still progress)."""
        self._mark_arrivals()
        admitted = self._admit()
        if not self.active.any():
            if self.queue:
                self.step_count += 1  # idle tick toward future arrivals
            return None
        rep, self.caches = self.server.step(
            self.tok_dev, self.pos.copy(), self.caches, active=self.active
        )
        now = self.clock()
        self.step_count += 1
        self.decode_steps += 1
        # Servers wrap the executor's TierStepResult in their own report;
        # the raw result carries the uniform per-slot fields.
        res = getattr(rep, "tier_result", rep)
        tokens = np.asarray(res.tokens)
        exited = np.asarray(res.exited)
        exit_tier = np.asarray(res.exit_tier)
        deg_mask = getattr(res, "degraded", None)
        fail_mask = getattr(res, "failed", None)
        self.tok_dev = res.tokens_dev[:, None]

        emitted: dict[int, int] = {}
        retired: list[int] = []
        degraded: list[int] = []
        failed: list[int] = []
        live = int(self.active.sum())
        for slot in np.flatnonzero(self.active):
            req = self._slot_req[slot]
            r = self.results[req.rid]
            if fail_mask is not None and fail_mask[slot]:
                # Unrecoverable hop fault: no token this step.  Reclaim
                # the slot either way; the request re-queues (fresh
                # admission, fresh result) or retires terminally failed.
                self.active[slot] = False
                self._slot_req[slot] = None
                failed.append(req.rid)
                if (
                    self.requeue_on_fail
                    and req._requeues < self.max_requeues
                ):
                    req._requeues += 1
                    r.status = "requeued"
                    self.queue.appendleft(req)
                else:
                    r.done = True
                    r.status = "failed"
                    r.retired_step = self.step_count
                    r.latency_s = now - req.arrival_s
                    self.finished.append(req.rid)
                    retired.append(req.rid)
                continue
            tok = int(tokens[slot])
            emitted[req.rid] = tok
            r.tokens.append(tok)
            r.exited.append(bool(exited[slot]))
            r.exit_tiers.append(int(exit_tier[slot]))
            if deg_mask is not None and deg_mask[slot]:
                r.degraded_tokens += 1
                degraded.append(req.rid)
            if r.ttft_s is None:
                r.ttft_s = now - req.arrival_s
            self.pos[slot] += 1
            self._remaining[slot] -= 1
            self.total_tokens += 1
            if self._remaining[slot] <= 0 or (
                req.stop_on_exit and exited[slot]
            ):
                r.done = True
                r.status = "degraded" if r.degraded_tokens else "ok"
                r.retired_step = self.step_count
                r.latency_s = now - req.arrival_s
                self.active[slot] = False
                self._slot_req[slot] = None
                self.finished.append(req.rid)
                retired.append(req.rid)
        if retired and self.reset_on_retire:
            rows = np.full(
                bucket_for(len(retired), self.slots), self.slots, np.int32
            )
            rows[: len(retired)] = [self.results[r].slot for r in retired]
            self.caches = self.executor.reset_rows(self.caches, rows)
        report = SchedulerStepReport(
            step=self.step_count,
            live=live,
            admitted=admitted,
            retired=tuple(retired),
            emitted=emitted,
            occupancy=live / self.slots,
            server_report=rep,
            degraded=tuple(degraded),
            failed=tuple(failed),
        )
        for cb in self.on_step:
            cb(res)
        return report

    # --------------------------------------------------------------- run
    def run(self, max_steps: int | None = None) -> list[SchedulerStepReport]:
        """Step until drained (queue empty and no live slot), or for
        ``max_steps`` *decode* steps (idle ticks waiting on simulated
        arrivals don't count — they always terminate, since the step clock
        advances toward every queued arrival_step).  Returns the per-step
        reports."""
        out: list[SchedulerStepReport] = []
        while self.queue or self.active.any():
            if max_steps is not None and len(out) >= max_steps:
                break
            rep = self.step()
            if rep is not None:
                out.append(rep)
        return out

    def drain(self) -> list[RequestResult]:
        """Run to completion and return every finished request's result in
        retirement order."""
        self.run()
        return [self.results[rid] for rid in self.finished]

    # ------------------------------------------------------------- stats
    @property
    def occupancy(self) -> float:
        return float(self.active.sum()) / self.slots

    def pending(self) -> int:
        return len(self.queue)


class ServesRequests:
    """Mixin giving a tier server the request-lifecycle API: ``submit()``
    / ``run()`` / ``drain()`` on top of a lazily built
    :class:`RequestScheduler` over the server's own ``slots`` and
    ``context_len``.  The lock-step ``step()`` remains available as the
    degenerate one-batch case (the scheduler itself calls it with the
    live mask)."""

    _scheduler: RequestScheduler | None = None

    @property
    def scheduler(self) -> RequestScheduler:
        if self._scheduler is None:
            self._scheduler = RequestScheduler(
                self, self.slots, self.context_len
            )
        return self._scheduler

    def submit(
        self,
        prompt,
        max_new_tokens: int,
        *,
        stop_on_exit: bool = False,
        arrival_step: int = 0,
    ) -> int:
        """Queue one request for continuous-batching admission; returns
        its rid (see :meth:`RequestScheduler.submit`)."""
        return self.scheduler.submit(
            prompt, max_new_tokens,
            stop_on_exit=stop_on_exit, arrival_step=arrival_step,
        )

    def run(self, max_steps: int | None = None) -> list[SchedulerStepReport]:
        """Decode up to ``max_steps`` request-loop steps (admitting and
        retiring between steps)."""
        return self.scheduler.run(max_steps)

    def drain(self) -> list[RequestResult]:
        """Run the request loop to completion; returns finished requests
        in retirement order."""
        return self.scheduler.drain()
