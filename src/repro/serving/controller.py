"""Repartition controller: live exit statistics -> solver -> hot swap.

The paper's loop (Sec. IV-C): exit probabilities are an input-data
property, so the deployment estimates them online and re-runs the
partition optimizer whenever they (or the network) drift.  This module
closes that loop against the unified tier runtime:

    ExitStats.conditional_probs() -> Partitioner / solve_multitier
        -> PartitionedServer.set_split / MultiTierServer.install_cuts

Three triggers are supported:

  * **explicit** — ``update(stats)`` re-solves unconditionally;
  * **drift** — ``observe(report)`` accumulates per-step exit counts from
    the serving loop; every ``every_n_steps`` steps it compares the
    measured exit distribution against the one the installed plan was
    solved for and re-solves when the KL divergence exceeds
    ``kl_threshold`` (``None`` = re-solve on every check);
  * **network** — ``update_network(profile)`` / ``update_tiers(specs)``
    re-solve with the last measured probabilities when the link changes.

Swaps go through ``TierExecutor.install``, which re-uses the compiled
function of every tier segment whose (layer range, branches) is unchanged
— repartitioning never pays a full re-jit.  When ``batch`` is set and the
server compacts, solves (K=2 and K>=3 alike) use the bucketed lattice
cost (core.multitier) so the plan is honest about the compacted runtime's
padding waste.  A server running
``overlap="pipelined"`` is re-solved against the pipeline-bottleneck
steady-state cost (``overlap=True`` in core.multitier) — the optimal cut
generally moves when transfers overlap compute.

Continuous batching: step reports carry the live width and the dead-slot
mask, so observe() (wired as a ``RequestScheduler.on_step`` callback)
counts arrivals over live rows only and feeds a decaying occupancy
estimate into batched solves (``occupancy=`` in core.multitier) — the
controller prices the steady-state live batch, not the nominal one.
``probe_sample_frac`` makes epsilon probes evaluate the extra branch
heads on a sampled sub-batch; the executor reports which rows were
covered and the window stays unbiased.

Hop health (fault plane): when the server runs with a
``LinkFaultModel`` attached, step reports carry ``fault_events`` and a
``degraded_hop``.  ``observe()`` ingests them into per-hop EWMAs —
availability (success fraction of *attempted* hops) and observed
transfer seconds — and, on a breaker state change (a hop's circuit
opening or closing), immediately re-solves with each hop's ``TierSpec``
availability set from the EWMA (0 for a breaker-open hop, which the
solver prices as an unusable link).  The re-solve goes through
``update_tiers`` / the lattice route, so the drift window resets and the
new plan moves the cut off the sick hop.  A hop the breaker skipped is
*not* an observation (no EWMA update), and a failed half-open probe
updates availability only — never the transfer-time EWMA — so breaker
probing cannot corrupt the cost estimates.  ``fault_resolve=False``
keeps the ingestion but disables the automatic re-solve (call
``apply_hop_health()`` explicitly).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.multitier import TierSpec, solve_multitier
from repro.core.partitioner import Partitioner
from repro.core.types import CostProfile, NetworkProfile
from repro.serving.engine import ExitStats
from repro.serving.multitier import MultiTierServer
from repro.serving.partitioned import PartitionedServer

__all__ = ["RepartitionController", "exit_distribution", "exit_drift_kl"]


def exit_distribution(p_k: np.ndarray) -> np.ndarray:
    """Conditional per-branch exit probs -> categorical distribution over
    (exit at branch 1, ..., exit at branch K, reach the main head)."""
    p_k = np.asarray(p_k, float)
    out = np.empty(len(p_k) + 1)
    alive = 1.0
    for j, p in enumerate(p_k):
        out[j] = alive * p
        alive *= 1.0 - p
    out[-1] = alive
    return out


def exit_drift_kl(
    measured_p: np.ndarray, installed_p: np.ndarray, eps: float = 1e-6
) -> float:
    """KL(measured || installed) between the two exit distributions."""
    m = exit_distribution(measured_p) + eps
    q = exit_distribution(installed_p) + eps
    m /= m.sum()
    q /= q.sum()
    return float(np.sum(m * np.log(m / q)))


@dataclasses.dataclass
class RepartitionController:
    """Feeds measured ``p_k`` back through the solver and installs the
    result on a 2-tier or K-tier server."""

    server: PartitionedServer | MultiTierServer
    profile: CostProfile
    tiers: list[TierSpec] | None = None  # required for MultiTierServer
    kl_threshold: float | None = None  # drift gate for observe()-driven solves
    every_n_steps: int = 0  # decode-loop hook cadence (0 = explicit only)
    batch: int | None = None  # bucketed-aware solving (K=2 and K>=3)
    window_steps: int = 256  # drift-window decay horizon (see observe())
    # Epsilon exploration schedule: every ``explore_every_n`` observed
    # steps, request a PROBE step from the executor — the next decode step
    # evaluates every branch head (would-exit masks reported, trajectory
    # untouched), so branches the installed plan discarded keep fresh
    # measured probabilities instead of carrying the installed estimate.
    # 0 disables exploration.
    explore_every_n: int = 0
    # Fraction of the batch a probe step evaluates the extra branch heads
    # on (1.0 = every row).  Sampled probes price exploration at a
    # sub-batch of branch-head FLOPs; the executor reports which rows were
    # covered and observe() counts arrivals over those rows only, so the
    # conditional estimates stay unbiased.
    probe_sample_frac: float = 1.0
    # Steady-state occupancy override for continuous-batching servers
    # (None = track the live width from observed step reports).  Solves
    # price the occupancy-weighted expected batch, not the nominal one.
    occupancy: float | None = None
    # Hop-health ingestion (fault plane): EWMA smoothing factor for the
    # per-hop availability / observed-transfer estimates, and whether a
    # breaker state change triggers an automatic availability re-solve.
    hop_alpha: float = 0.3
    fault_resolve: bool = True

    def __post_init__(self):
        if isinstance(self.server, MultiTierServer) and self.tiers is None:
            self.tiers = list(self.server.tiers)
        k = len(self.server.cfg.branch_layers)
        # Per-branch (arrivals, exits) over the current window.  A branch
        # the installed plan never evaluates (discarded at a cut, or inside
        # the final tier) accrues no arrivals — its probability is then
        # carried over from the installed estimate rather than read as 0,
        # so re-solves don't lock in on fictitious p=0 branches.  (A plan
        # that evaluates *no* branches observes nothing at all; escaping
        # that state needs an explicit update() from a K=1 calibration
        # pass or a network trigger — drift alone cannot see it.)
        self._arrivals = np.zeros(k, np.float64)
        self._exits = np.zeros(k, np.float64)
        self._steps_observed = 0
        self._window_age = 0
        self._installed_p: np.ndarray | None = None
        if not 0.0 < self.probe_sample_frac <= 1.0:
            raise ValueError(
                f"probe_sample_frac must be in (0, 1]: {self.probe_sample_frac}"
            )
        # Decaying estimate of the live fraction (continuous batching);
        # lock-step reports keep it at 1.
        self._occ_est: float | None = None
        # Per-hop health state (fault plane).  Keyed by hop index (tier
        # boundary j, stable across repartitions).  ``_hop_avail`` is the
        # EWMA success fraction over *attempted* hops (breaker-skipped
        # hops are not observations); ``_hop_xfer`` the EWMA of observed
        # per-hop simulated transfer seconds over successful non-empty
        # shipments only (a failed half-open probe never touches it);
        # ``_hop_open`` the hops whose breaker is currently open (priced
        # as availability 0 by re-solves).
        self._hop_avail: dict[int, float] = {}
        self._hop_xfer: dict[int, float] = {}
        self._hop_open: set[int] = set()
        self.fault_resolves = 0

    # ------------------------------------------------------------ solving
    def _solve_occupancy(self) -> float | None:
        """The live-width fraction batched solves should price: the
        explicit ``occupancy`` override, else the decaying estimate from
        observed continuous-batching step reports, else None (nominal)."""
        occ = self.occupancy if self.occupancy is not None else self._occ_est
        if occ is None:
            return None
        return float(min(max(occ, 1e-6), 1.0))

    def solve(self, p_k: np.ndarray) -> tuple[int, ...]:
        """Optimal cut vector for the profile with live exit probs.  A
        server running ``overlap="pipelined"`` is solved against the
        pipeline-bottleneck steady-state cost (the optimal cut can move
        under overlap), a serial server against the serial chain sum.
        Batched solves price the occupancy-weighted steady-state live
        width (see ``occupancy``)."""
        prof = Partitioner(self.profile).with_exit_probs(p_k).profile
        overlap = getattr(self.server, "overlap", "serial") == "pipelined"
        occ = self._solve_occupancy()
        if isinstance(self.server, MultiTierServer):
            plan = solve_multitier(
                prof.t_c, prof.alpha, prof.branch_exit_probs(), self.tiers,
                batch=self.batch,
                overlap=overlap,
                occupancy=occ if self.batch is not None else None,
            )
            return plan.cut_after
        bucketed = (
            self.batch is not None
            and getattr(self.server, "compaction", "off") == "bucketed"
        )
        avail = 0.0 if 0 in self._hop_open else self._hop_avail.get(0, 1.0)
        if overlap or bucketed or avail < 1.0:
            # 2-tier pipelined and/or bucketed: the paper's Dijkstra
            # minimizes the ideal serial sum; route through the unified
            # lattice cost instead so the installed cut optimizes the same
            # objective the server's est_latency_s reports (bottleneck
            # stage under overlap, padding-honest under compaction).  The
            # lattice model (like the paper's Eq. 5) neglects branch-head
            # compute, so a profile with include_branch_compute=True is
            # optimized without the gamma * t_b edge terms here.  A
            # mesh-sharded server's shard widths / interconnect carry into
            # the specs so re-solves price the sharded cloud tier.
            # Degraded uplink health routes the 2-tier solve through the
            # lattice as well: the edge spec carries the EWMA availability
            # (0 = breaker open), which _hop_seconds prices as a slower —
            # or unusable — link, pushing the cut toward all-edge.
            dev = getattr(self.server, "tier_devices", None) or (1, 1)
            ici = getattr(self.server, "ici_bps", 0.0)
            tiers = [
                TierSpec("edge", prof.gamma, prof.network.bandwidth_bps,
                         devices=dev[0], ici_bps=ici, availability=avail),
                TierSpec("cloud", 1.0, devices=dev[1], ici_bps=ici),
            ]
            plan = solve_multitier(
                prof.t_c, prof.alpha, prof.branch_exit_probs(), tiers,
                batch=self.batch if bucketed else None, overlap=overlap,
                occupancy=occ if bucketed else None,
            )
            return plan.cut_after
        return (Partitioner(prof).solve().split_layer,)

    def _install(self, p_k: np.ndarray) -> tuple[int, ...]:
        cuts = self.solve(p_k)
        self._installed_p = np.asarray(p_k, float)
        # Start a fresh measurement window: drift is judged against the
        # traffic seen *under the new plan*, and the lifetime-average bias
        # (old regimes drowning out new ones) is bounded by the window.
        self._arrivals[:] = 0
        self._exits[:] = 0
        self._window_age = 0
        if isinstance(self.server, MultiTierServer):
            self.server.install_cuts(cuts)
            return self.server.cuts
        self.server.set_split(cuts[0])
        return (self.server.split_layer,)

    def update(self, stats: ExitStats) -> tuple[int, ...]:
        """Re-solve from live stats and hot-swap the split if it moved.
        Returns the installed cut vector."""
        return self._install(stats.conditional_probs())

    # ----------------------------------------------------- drift detection
    def observe(self, report) -> tuple[int, ...] | None:
        """Decode-loop hook: accumulate one step's exit outcome (any report
        carrying ``branch_take`` + ``tokens``).  Every ``every_n_steps``
        observed steps, re-solve if the measured exit distribution drifted
        past ``kl_threshold``.  Returns the new cuts when a swap happened.

        Continuous-batching reports carry ``active``/``live``: dead slots
        never count as arrivals, and the live width feeds the decaying
        occupancy estimate batched solves price.  Sampled probe reports
        carry ``branch_probe_mask``: a probed branch's arrivals are
        counted over its covered rows only, so sampling never reads an
        unevaluated head as "arrived without exiting".  (When several
        probed branches sit on different compacted segments their
        coverage sets can differ; a row uncovered at an earlier branch
        whose counterfactual exit is therefore unknown still counts at a
        later branch it is covered on — a second-order conditioning
        approximation that vanishes at ``probe_sample_frac=1``.)
        """
        batch = report.tokens.shape[0]
        active = getattr(report, "active", None)
        alive = (
            np.ones((batch,), bool) if active is None
            else np.asarray(active, bool).copy()
        )
        probe_cover = getattr(report, "branch_probe_mask", {}) or {}
        for j, layer in enumerate(self.server.cfg.branch_layers):
            take = report.branch_take.get(layer)
            if take is None:
                continue  # branch not evaluated under this plan (nor probed)
            cover = probe_cover.get(layer)
            counted = alive if cover is None else (alive & cover)
            self._arrivals[j] += float(counted.sum())
            # Intersect with the running alive mask: on a probe step an
            # earlier (discarded) branch's would-exit rows have left
            # `alive`, but the executor computed this branch's take under
            # *plan* semantics, so the masks can overlap — counting the
            # overlap would push the conditional estimate past 1.
            self._exits[j] += float((take & counted).sum())
            alive &= ~take
        live = getattr(report, "live", None)
        if live:
            occ = live / batch
            self._occ_est = (
                occ if self._occ_est is None
                else 0.9 * self._occ_est + 0.1 * occ
            )
        self._steps_observed += 1
        self._window_age += 1
        if (
            self.explore_every_n
            and self._steps_observed % self.explore_every_n == 0
        ):
            # Epsilon step: the next decode step probes every branch head
            # (on a probe_sample_frac sub-batch).  Its report carries
            # would-exit masks for the discarded branches too, which the
            # loop above folds into the window.
            self.server.executor.probe_next = True
            self.server.executor.probe_sample_frac = self.probe_sample_frac
        if self._window_age >= self.window_steps:
            # Exponential decay: halve the window so the measured
            # distribution tracks regime changes in O(window_steps) steps
            # instead of degrading with controller lifetime.
            self._arrivals *= 0.5
            self._exits *= 0.5
            self._window_age = 0
        fault_cuts = self._ingest_faults(report)
        if fault_cuts is not None:
            # A breaker state change re-solved and swapped the plan (which
            # also reset the drift window); it takes precedence over the
            # periodic drift check this step.
            return fault_cuts
        if self.every_n_steps and self._steps_observed % self.every_n_steps == 0:
            return self.maybe_update()
        return None

    # -------------------------------------------------------- hop health
    def _ingest_faults(self, report) -> tuple[int, ...] | None:
        """Fold one step's fault-plane outputs into the per-hop health
        EWMAs; re-solve (availability-aware) on a breaker state change.

        Only *attempted* hops are observations: a hop the breaker skipped
        (``breaker_skip`` event), and hops downstream of the broken one
        (never dispatched), leave both EWMAs untouched.  Transfer seconds
        are ingested only from successful non-empty shipments, so a failed
        half-open probe moves availability but can never corrupt the
        transfer-time estimate.
        """
        events = getattr(report, "fault_events", None)
        if not events and getattr(report, "degraded_hop", None) is None:
            return None
        events = events or ()
        broken = getattr(report, "degraded_hop", None)
        skipped = {e.hop for e in events if e.kind == "breaker_skip"}
        failed_hops = {e.hop for e in events if e.kind == "exhausted"}
        nb = getattr(report, "bytes_per_hop", ()) or ()
        sim = getattr(report, "sim_transfer_s", ()) or ()
        a = self.hop_alpha
        for j in range(len(nb)):
            if j in skipped or (broken is not None and j > broken):
                continue  # not attempted: no observation
            ok = j not in failed_hops
            prev = self._hop_avail.get(j, 1.0)
            self._hop_avail[j] = (1.0 - a) * prev + a * (1.0 if ok else 0.0)
            if ok and float(nb[j]) > 0 and j < len(sim) and sim[j] > 0:
                prev_x = self._hop_xfer.get(j)
                self._hop_xfer[j] = (
                    float(sim[j]) if prev_x is None
                    else (1.0 - a) * prev_x + a * float(sim[j])
                )
        resolve = False
        for e in events:
            if e.kind == "breaker_open" and e.hop not in self._hop_open:
                self._hop_open.add(e.hop)
                resolve = True
            elif e.kind == "breaker_closed" and e.hop in self._hop_open:
                self._hop_open.discard(e.hop)
                # The link recovered: forgive the failure history so the
                # re-solve prices it healthy instead of replaying the EWMA
                # tail of the outage.
                self._hop_avail[e.hop] = 1.0
                resolve = True
        if resolve and self.fault_resolve:
            return self.apply_hop_health()
        return None

    def hop_health(self) -> dict[int, dict[str, float | bool]]:
        """Per-hop health snapshot: availability EWMA, observed-transfer
        EWMA (None until a successful shipment), breaker-open flag."""
        hops = set(self._hop_avail) | set(self._hop_xfer) | self._hop_open
        return {
            j: {
                "availability": self._hop_avail.get(j, 1.0),
                "transfer_s": self._hop_xfer.get(j),
                "open": j in self._hop_open,
            }
            for j in sorted(hops)
        }

    def apply_hop_health(self) -> tuple[int, ...]:
        """Re-solve with each hop's ``TierSpec.availability`` set from the
        health EWMAs (0 for a breaker-open hop) and hot-swap the result.
        Fires automatically on breaker state changes when
        ``fault_resolve`` is set; callable explicitly otherwise.

        The K>=3 path goes through :meth:`update_tiers`, so the drift
        window resets exactly as it does for any topology change.  Note
        that once the re-solve moves the cut off a sick hop, that hop is
        no longer exercised — its breaker never half-opens again, so
        recovery needs an explicit ``update_tiers`` with the restored
        specs (or ``fault_resolve=False`` with manual control)."""
        self.fault_resolves += 1
        if isinstance(self.server, MultiTierServer):
            specs = [
                dataclasses.replace(
                    t,
                    availability=(
                        0.0 if j in self._hop_open
                        else self._hop_avail.get(j, t.availability)
                    ),
                )
                if j < len(self.tiers) - 1 else t
                for j, t in enumerate(self.tiers)
            ]
            return self.update_tiers(specs)
        # 2-tier: availability reaches the solve through the lattice route
        # (see solve()); segments are unchanged, so no executor refresh.
        return self._install(self._best_p())

    def measured_probs(self) -> np.ndarray:
        """Conditional p_k per branch from the observed window.  Branches
        with no observed arrivals fall back to the installed estimate."""
        out = []
        for j in range(len(self._arrivals)):
            if self._arrivals[j] > 0:
                out.append(self._exits[j] / self._arrivals[j])
            elif self._installed_p is not None:
                out.append(float(self._installed_p[j]))
            else:
                out.append(0.0)
        return np.asarray(out)

    def drift_kl(self) -> float:
        """KL between measured and installed exit distributions
        (+inf when nothing was installed through this controller yet)."""
        if self._installed_p is None:
            return float("inf")
        return exit_drift_kl(self.measured_probs(), self._installed_p)

    def maybe_update(self, force: bool = False) -> tuple[int, ...] | None:
        """Re-solve from the observed counts if drift warrants it."""
        if self._arrivals.sum() == 0:
            return None  # nothing observed under this plan yet
        drifted = (
            force
            or self.kl_threshold is None
            or self.drift_kl() > self.kl_threshold
        )
        if not drifted:
            return None
        return self._install(self.measured_probs())

    # ------------------------------------------------------ network drift
    def update_network(self, network: NetworkProfile) -> tuple[int, ...]:
        """The 2-tier link changed: re-solve with the last measured (or
        installed) exit probs against the new bandwidth."""
        if not isinstance(self.server, PartitionedServer):
            raise TypeError("update_network is 2-tier; use update_tiers for K>=3")
        self.profile = dataclasses.replace(self.profile, network=network)
        self.server.network = network
        if self.server.cost_profile is not None:
            self.server.cost_profile = self.profile
        cuts = self._install(self._best_p())
        # Refresh segments even when the cut didn't move: the new uplink
        # must reach the executor's per-hop byte/latency accounting.
        self.server.executor.install(self.server._segments(self.server.split_layer))
        return cuts

    def update_tiers(self, tiers: list[TierSpec]) -> tuple[int, ...]:
        """K>=3 tier topology / uplinks changed: re-solve and hot-swap."""
        if not isinstance(self.server, MultiTierServer):
            raise TypeError("update_tiers is K>=3; use update_network for 2-tier")
        self.tiers = list(tiers)
        self.server.tiers = tuple(tiers)
        cuts = self._install(self._best_p())
        self.server.executor.install(self.server._segments(self.server.cuts))
        return cuts

    def _best_p(self) -> np.ndarray:
        """Most recent exit-prob estimate: measured > installed > zeros."""
        if self._arrivals.sum() > 0:
            return self.measured_probs()
        if self._installed_p is not None:
            return self._installed_p
        return np.zeros(len(self.server.cfg.branch_layers))
