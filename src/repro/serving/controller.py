"""Repartition controller: live exit statistics -> solver -> hot swap.

The paper's loop (Sec. IV-C): exit probabilities are an input-data
property, so the deployment estimates them online and re-runs the
partition optimizer whenever they (or the network) drift.  This module
closes that loop against the unified tier runtime:

    ExitStats.conditional_probs() -> Partitioner / solve_multitier
        -> PartitionedServer.set_split / MultiTierServer.install_cuts

Swaps go through ``TierExecutor.install``, which re-uses the compiled
function of every tier segment whose (layer range, branches) is unchanged
— repartitioning never pays a full re-jit.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.multitier import TierSpec, solve_multitier
from repro.core.partitioner import Partitioner
from repro.core.types import CostProfile
from repro.serving.engine import ExitStats
from repro.serving.multitier import MultiTierServer
from repro.serving.partitioned import PartitionedServer

__all__ = ["RepartitionController"]


@dataclasses.dataclass
class RepartitionController:
    """Feeds measured ``p_k`` back through the solver and installs the
    result on a 2-tier or K-tier server."""

    server: PartitionedServer | MultiTierServer
    profile: CostProfile
    tiers: list[TierSpec] | None = None  # required for MultiTierServer

    def __post_init__(self):
        if isinstance(self.server, MultiTierServer) and self.tiers is None:
            self.tiers = list(self.server.tiers)

    def solve(self, p_k: np.ndarray) -> tuple[int, ...]:
        """Optimal cut vector for the profile with live exit probs."""
        prof = Partitioner(self.profile).with_exit_probs(p_k).profile
        if isinstance(self.server, MultiTierServer):
            plan = solve_multitier(
                prof.t_c, prof.alpha, prof.branch_exit_probs(), self.tiers
            )
            return plan.cut_after
        return (Partitioner(prof).solve().split_layer,)

    def update(self, stats: ExitStats) -> tuple[int, ...]:
        """Re-solve from live stats and hot-swap the split if it moved.
        Returns the installed cut vector."""
        cuts = self.solve(stats.conditional_probs())
        if isinstance(self.server, MultiTierServer):
            self.server.install_cuts(cuts)
            return self.server.cuts
        self.server.set_split(cuts[0])
        return (self.server.split_layer,)
