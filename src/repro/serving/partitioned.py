"""Partitioned (edge/cloud) BranchyNet serving — the paper's system.

Executes a decode step split at the plan's partition layer ``v_s``:

  edge tier : embed + trunk layers [0, s) + the side branches before the
              cut.  Sequences whose branch entropy clears the threshold
              *exit on the edge* — they emit a token immediately and are
              never shipped (this is exactly the mechanism that makes the
              expected transfer cost ``surv(s) * t_s^net`` in Eq. 5).
  transfer  : the residual stream (B_surviving, 1, d_model) crosses the
              bandwidth cliff; we account bytes and model latency with the
              paper's cost model.
  cloud tier: trunk layers [s, L) + final head for surviving sequences.

On one host this is a simulation of the two tiers (both run locally), but
the tier boundary is real in the compiled program: edge/cloud are two
separate jitted functions with an explicit tensor handoff, which is the
same structure a real edge deployment lowers.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.types import CostProfile, NetworkProfile, PartitionPlan
from repro.models import model as M
from repro.models.layers import norm_apply
from repro.models.model import (
    _branch_logits,
    _embed_inputs,
    _unembed,
    compute_dtype,
    run_trunk,
    trunk_layout,
)
from repro.models.layers import embed, sinusoidal_embed

__all__ = ["PartitionedServer", "StepReport"]


@dataclasses.dataclass
class StepReport:
    tokens: np.ndarray  # (B,)
    exited_on_edge: np.ndarray  # (B,) bool
    shipped: int  # sequences that crossed the cut
    bytes_shipped: float
    est_latency_s: float | None  # paper Eq. 5 with the measured exit fraction


@dataclasses.dataclass
class PartitionedServer:
    cfg: ModelConfig
    params: Any
    split_layer: int  # the plan's v_s (0 = cloud-only, L = edge-only)
    network: NetworkProfile | None = None
    cost_profile: CostProfile | None = None  # for latency estimates

    def __post_init__(self):
        cfg = self.cfg
        s = self.split_layer
        total = sum(n for _, _, n in trunk_layout(cfg))
        assert 0 <= s <= total
        edge_branches = tuple(b for b in cfg.branch_layers if b < s) if s else ()

        def edge_step(params, tok, pos, caches):
            dtype = compute_dtype(cfg)
            h = embed(params["embed"], tok, dtype)
            positions = pos[None].astype(jnp.int32)
            if cfg.arch_type == "audio":
                h = h + sinusoidal_embed(positions, cfg.d_model).astype(dtype)[None]
            h, caches2, _, collected = run_trunk(
                params, h, cfg, positions, caches,
                layer_range=(0, s), collect=edge_branches,
            )
            bl = _branch_logits(params, collected, cfg)
            out = {"hidden": h, "caches": caches2}
            out["branch_logits"] = {k: v[:, 0] for k, v in bl.items()}
            return out

        def cloud_step(params, hidden, pos, caches):
            positions = pos[None].astype(jnp.int32)
            h, caches2, _, _ = run_trunk(
                params, hidden, cfg, positions, caches, layer_range=(s, total),
            )
            hF = norm_apply(cfg.norm_type, params["final_norm"], h)
            return {"logits": _unembed(params, hF, cfg)[:, 0], "caches": caches2}

        self._edge = jax.jit(edge_step) if s > 0 else None
        self._cloud = jax.jit(cloud_step) if s < total else None
        self._edge_branches = edge_branches
        self._total = total

        # Edge-only: the deepest branch plus the final head both live on the
        # edge; emit from the final head (all layers are local anyway).
        if s == total:
            def edge_full(params, tok, pos, caches):
                out = M.decode_step(params, tok, pos, caches, cfg)
                return out
            self._edge_full = jax.jit(edge_full)

    # ------------------------------------------------------------------
    def step(self, tok: jax.Array, pos: int, caches: Any) -> tuple[StepReport, Any]:
        cfg = self.cfg
        s = self.split_layer
        d = cfg.d_model
        posj = jnp.asarray(pos, jnp.int32)
        bytes_per_seq = d * 2.0  # bf16 residual stream

        if s == 0:
            # Cloud-only: ship the raw token id (alpha_0 == a few bytes; the
            # paper's raw-input upload is the prompt, which happened at
            # prefill time — per-step transfer is the token id).
            out = M.decode_step(self.params, tok, posj, caches, cfg,
                                with_branches=False)
            toks = np.asarray(jnp.argmax(out["logits"], -1).astype(jnp.int32))
            rep = StepReport(
                tokens=toks,
                exited_on_edge=np.zeros(toks.shape[0], bool),
                shipped=toks.shape[0],
                bytes_shipped=4.0 * toks.shape[0],
                est_latency_s=self._estimate(0, 0.0),
            )
            return rep, out["caches"]

        if s == self._total:
            out = self._edge_full(self.params, tok, posj, caches)
            main_tok = jnp.argmax(out["logits"], -1).astype(jnp.int32)
            chosen, exited = self._apply_exits(out, main_tok)
            rep = StepReport(
                tokens=np.asarray(chosen),
                exited_on_edge=np.asarray(exited),
                shipped=0,
                bytes_shipped=0.0,
                est_latency_s=self._estimate(s, float(np.mean(np.asarray(exited)))),
            )
            return rep, out["caches"]

        eout = self._edge(self.params, tok, posj, caches)
        exited = jnp.zeros(tok.shape[0], bool)
        chosen = jnp.zeros(tok.shape[0], jnp.int32)
        for layer in self._edge_branches:
            logits = eout["branch_logits"][layer]
            from repro.core.calibration import normalized_entropy

            e = normalized_entropy(logits)
            take = (e < cfg.exit_threshold) & ~exited
            chosen = jnp.where(take, jnp.argmax(logits, -1).astype(jnp.int32), chosen)
            exited = exited | take

        cout = self._cloud(self.params, eout["hidden"], posj, eout["caches"])
        main_tok = jnp.argmax(cout["logits"], -1).astype(jnp.int32)
        chosen = jnp.where(exited, chosen, main_tok)

        exited_np = np.asarray(exited)
        shipped = int((~exited_np).sum())
        rep = StepReport(
            tokens=np.asarray(chosen),
            exited_on_edge=exited_np,
            shipped=shipped,
            bytes_shipped=shipped * bytes_per_seq,
            est_latency_s=self._estimate(s, float(exited_np.mean())),
        )
        return rep, cout["caches"]

    def _apply_exits(self, out, main_tok):
        cfg = self.cfg
        chosen = main_tok
        exited = jnp.zeros(main_tok.shape, bool)
        for layer in cfg.branch_layers:
            b_tok = jnp.argmax(out["branch_logits"][layer], -1).astype(jnp.int32)
            take = out["branch_exit"][layer] & ~exited
            chosen = jnp.where(take, b_tok, chosen)
            exited = exited | take
        return chosen, exited

    def _estimate(self, s: int, exit_frac: float) -> float | None:
        """Paper Eq. 5 evaluated at this split with the *measured* exit
        fraction substituted for p (closing the calibration loop)."""
        if self.cost_profile is None:
            return None
        import dataclasses as dc

        from repro.core.latency import expected_time

        prof = self.cost_profile
        if prof.branches and exit_frac > 0:
            branches = tuple(
                dc.replace(b, exit_prob=min(exit_frac, 1.0)) for b in prof.branches
            )
            prof = dc.replace(prof, branches=branches)
        return expected_time(prof, s)
