"""Partitioned (edge/cloud) BranchyNet serving — the paper's system.

A thin 2-tier configuration of :class:`~repro.serving.tiers.TierExecutor`.
One decode step splits at the plan's partition layer ``v_s``:

  edge tier : embed + trunk layers [0, s) + the side branches before the
              cut.  Sequences whose branch entropy clears the threshold
              *exit on the edge* — they emit a token immediately and are
              never shipped (this is exactly the mechanism that makes the
              expected transfer cost ``surv(s) * t_s^net`` in Eq. 5).
  transfer  : the residual stream (B_surviving, 1, d_model) crosses the
              bandwidth cliff; we account bytes and model latency with the
              paper's cost model.
  cloud tier: trunk layers [s, L) + final head for surviving sequences.

On one host this is a simulation of the two tiers (both run locally), but
the tier boundary is real in the compiled program: edge/cloud are two
separate jitted segment functions with an explicit tensor handoff, which
is the same structure a real edge deployment lowers.  ``set_split`` swaps
the cut at runtime; a segment whose (layer range, branches) is unchanged
re-uses its compiled function.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.latency import expected_time
from repro.core.multitier import TierSpec, expected_time_multitier
from repro.core.profiler import branch_head_cost
from repro.core.types import CostProfile, NetworkProfile
from repro.launch.mesh import mesh_devices
from repro.serving.scheduler import ServesRequests
from repro.serving.tiers import (
    HopCompaction,
    TierExecutor,
    TierStepResult,
    segments_for_cuts,
)

__all__ = ["PartitionedServer", "StepReport"]


@dataclasses.dataclass
class StepReport:
    tokens: np.ndarray  # (B,)
    exited_on_edge: np.ndarray  # (B,) bool
    shipped: int  # sequences that crossed the cut
    bytes_shipped: float
    est_latency_s: float | None  # paper Eq. 5 with the measured exit fraction
    compaction: tuple[HopCompaction, ...] = ()  # cloud sub-batch shape
    branch_take: dict[int, np.ndarray] = dataclasses.field(default_factory=dict)
    sim_transfer_s: tuple[float, ...] = ()  # simulated uplink wall time
    # Cumulative executor health counters (bucket-policy observability):
    # steps re-run on bucket overflow, and pipelined steps that fell back
    # to serial because of one.
    overflow_retries: int = 0
    pipeline_fallbacks: int = 0
    #: Live request slots this step decoded (== B under lock-step); the
    #: estimator prices the steady-state live width through it.
    live: int = 0
    #: The executor's raw result (per-slot tokens_dev / exit_tier / probe
    #: coverage) — what the request scheduler and controller consume.
    tier_result: TierStepResult | None = None
    #: Fault-plane outputs (serving.tiers degraded-step contract): rows
    #: finalized from the edge fallback head / rows that could not emit,
    #: the step's replayable fault trace, and the broken hop (None =
    #: healthy step).
    degraded: np.ndarray | None = None
    failed: np.ndarray | None = None
    fault_events: tuple = ()
    degraded_hop: int | None = None


@dataclasses.dataclass
class PartitionedServer(ServesRequests):
    cfg: ModelConfig
    params: Any
    split_layer: int  # the plan's v_s (0 = cloud-only, L = edge-only)
    network: NetworkProfile | None = None
    cost_profile: CostProfile | None = None  # for latency estimates
    compaction: str = "bucketed"  # "off" = legacy masked full-batch cloud
    simulate_network: bool = False  # sleep each hop's transfer time
    overlap: str = "serial"  # "pipelined" = overlap transfers with compute
    use_kernels: bool | None = None  # Pallas decode path; None = cfg/auto
    # Batched exit heads: one (K, B, D) projection + one multi-head fused
    # entropy-exit launch per segment instead of K head evaluations
    # (serving.tiers "Batched exit heads").  Bitwise identical tokens /
    # masks either way; False keeps the sequential per-head path.  The
    # same knob selects the branch-head pricing mode
    # (core.profiler.branch_head_cost) when ``price_heads`` is on.
    heads_batched: bool = True
    # Add the branch-head compute term (priced through ``heads_batched``)
    # to est_latency_s' lattice cost.  Off by default: the historical
    # estimate prices trunk layers + hops only.
    price_heads: bool = False
    hint_window: int = 8  # windowed-max bucket hints (1 = last step only)
    bucket_headroom: float = 0.0  # fractional bucket padding vs retries
    slots: int = 8  # request-scheduler KV slots (submit/run/drain API)
    context_len: int = 4096  # scheduler cache capacity per slot
    # Device mesh (+ optional explicit ShardingPolicy): the cloud tier is
    # a mesh slice, not a chip — segments run SPMD (serving.tiers
    # "Mesh-sharded tier segments").  ``tier_devices`` is the (edge,
    # cloud) shard width the estimator prices (None = derive (1, mesh
    # size) from the mesh); ``ici_bps`` the cloud tier's intra-mesh
    # interconnect for its collective term.
    mesh: Any = None
    sharding: Any = None
    tier_devices: tuple[int, int] | None = None
    ici_bps: float = 0.0
    # Fault plane (serving.faults): a seeded LinkFaultModel arms uplink
    # fault injection + breaker-gated retries + edge-head degradation;
    # hop_policy overrides the retry/timeout/breaker defaults.
    fault_model: Any = None
    hop_policy: Any = None

    def __post_init__(self):
        if self.tier_devices is None:
            self.tier_devices = (
                (1, mesh_devices(self.mesh)) if self.mesh is not None
                else (1, 1)
            )
        self.executor = TierExecutor(
            self.cfg, self.params, self._segments(self.split_layer),
            compaction=self.compaction,
            simulate_network=self.simulate_network,
            overlap=self.overlap,
            use_kernels=self.use_kernels,
            batched_heads=self.heads_batched,
            hint_window=self.hint_window,
            bucket_headroom=self.bucket_headroom,
            mesh=self.mesh,
            sharding=self.sharding,
            fault_model=self.fault_model,
            hop_policy=self.hop_policy,
        )
        self.params = self.executor.params

    def _segments(self, s: int):
        return segments_for_cuts(
            self.cfg, (s,), names=("edge", "cloud"),
            uplinks=(self.network.bandwidth_bps,) if self.network else None,
            devices=self.tier_devices,
        )

    def set_split(self, split_layer: int) -> None:
        """Hot-swap the cut; unchanged tier segments are not re-jitted."""
        if split_layer == self.split_layer:
            return
        self.executor.install(self._segments(split_layer))
        self.split_layer = split_layer

    # ------------------------------------------------------------------
    def step(
        self, tok: jax.Array, pos, caches: Any, *, active=None
    ) -> tuple[StepReport, Any]:
        res, caches = self.executor.step(tok, pos, caches, active=active)
        shipped = res.shipped_per_hop[0] if res.shipped_per_hop else 0
        nbytes = res.bytes_per_hop[0] if res.bytes_per_hop else 0.0
        rep = StepReport(
            tokens=res.tokens,
            exited_on_edge=res.exited,
            shipped=shipped,
            bytes_shipped=nbytes,
            est_latency_s=self._estimate(self.split_layer, res),
            compaction=res.compaction,
            branch_take=res.branch_take,
            sim_transfer_s=res.sim_transfer_s,
            overflow_retries=self.executor.overflow_retries,
            pipeline_fallbacks=self.executor.pipeline_fallbacks,
            live=res.live,
            tier_result=res,
            degraded=res.degraded,
            failed=res.failed,
            fault_events=res.fault_events,
            degraded_hop=res.degraded_hop,
        )
        return rep, caches

    def _estimate(self, s: int, res) -> float | None:
        """Paper Eq. 5 evaluated at this split with the *measured*
        per-branch conditional exit probabilities substituted for p
        (closing the calibration loop).

        Each branch's conditional probability is derived from this step's
        first-exit masks (``res.branch_take``) the same way
        ``MultiTierServer._estimate`` does: exits at a branch over the
        sequences still alive when they reached it.  (Substituting the
        *cumulative* exit fraction for every branch — the historical
        behavior — double-counts exits as soon as the plan evaluates two or
        more branches.)  A branch the installed plan never evaluates
        (discarded at the cut, or downstream of it) reads p = 0: that is
        the probability the executed plan actually experiences.

        When the runtime compacts (``compaction="bucketed"``) or pipelines
        (``overlap="pipelined"``) the estimate uses the unified lattice
        cost so K=2 reports the same padding-honest / bottleneck-stage
        numbers as MultiTierServer rather than the ideal serial
        ``surv(s) * B`` cloud term.  Under continuous batching the step's
        live width feeds the occupancy term, so the estimate prices the
        *steady-state* live batch rather than the nominal one."""
        if self.cost_profile is None:
            return None
        prof = self.cost_profile
        batch = res.tokens.shape[0]
        live = getattr(res, "live", 0) or batch
        if prof.branches:
            alive = float(live)
            measured: dict[int, float] = {}
            for layer in sorted(res.branch_take):
                took = float(res.branch_take[layer].sum())
                measured[layer] = took / alive if alive > 0 else 0.0
                alive -= took
            branches = tuple(
                dataclasses.replace(b, exit_prob=measured.get(b.after_layer, 0.0))
                for b in prof.branches
            )
            prof = dataclasses.replace(prof, branches=branches)
        pipelined = self.overlap == "pipelined"
        bucketed = self.compaction == "bucketed"
        if (bucketed or pipelined) and prof.network is not None:
            tiers = [
                TierSpec("edge", prof.gamma, prof.network.bandwidth_bps,
                         devices=self.tier_devices[0], ici_bps=self.ici_bps),
                TierSpec("cloud", 1.0,
                         devices=self.tier_devices[1], ici_bps=self.ici_bps),
            ]
            head_cost = (
                branch_head_cost(
                    self.cfg, batch, heads_batched=self.heads_batched
                )
                if self.price_heads else None
            )
            return expected_time_multitier(
                prof.t_c, prof.alpha, prof.branch_exit_probs(), tiers, (s,),
                batch=batch if bucketed else None,
                overlap=pipelined,
                occupancy=live / batch if bucketed else None,
                head_cost=head_cost,
                branch_layers=self.cfg.branch_layers,
            )
        return expected_time(prof, s)
