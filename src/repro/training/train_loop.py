"""Train step with gradient accumulation and BranchyNet joint loss.

``make_train_step`` returns a pure function suitable for jax.jit with
in/out shardings from the policy.  Gradient accumulation is a lax.scan over
microbatches (bounds activation memory for the 671B/76B train_4k dry-runs).
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.sharding.ctx import constrain
from repro.training.optimizer import Optimizer

__all__ = ["TrainState", "init_train_state", "make_train_step"]

Params = Any


def init_train_state(params: Params, opt: Optimizer) -> dict:
    return {
        "params": params,
        "opt": opt.init(params),
        "step": jnp.zeros((), jnp.int32),
    }


def make_train_step(
    cfg: ModelConfig,
    opt: Optimizer,
    *,
    moe_dispatch: str = "einsum",
    accum: int | None = None,
) -> Callable[[dict, dict], tuple[dict, dict]]:
    """Returns train_step(state, batch) -> (state, metrics).

    ``batch`` leaves have a leading global-batch axis; with accumulation
    they are reshaped to (accum, micro, ...) and scanned, accumulating
    grads in ``cfg.accum_dtype``.  ``accum`` defaults to ``cfg.grad_accum``
    but the launcher caps it so each microbatch still covers every batch
    shard of the mesh (a 512-chip mesh halves the accumulation depth).
    """

    accum = max(accum if accum is not None else cfg.grad_accum, 1)

    def loss_fn(params, micro):
        out = M.forward_train(params, micro, cfg, moe_dispatch=moe_dispatch)
        return out["loss"], out

    def train_step(state, batch):
        params = state["params"]

        if accum == 1:
            (loss, out), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
        else:
            micro = jax.tree_util.tree_map(
                lambda a: a.reshape(accum, a.shape[0] // accum, *a.shape[1:]), batch
            )
            # The (global_batch,) -> (accum, micro) reshape loses the batch
            # sharding under SPMD propagation (XLA picked a 2-way-sharded
            # microbatch for whisper: +18 GB/dev of replicated cross-KV).
            micro = jax.tree_util.tree_map(
                lambda a: constrain(a, "." + "b" + "." * (a.ndim - 2)), micro
            )

            acc_dtype = (
                jnp.bfloat16 if cfg.accum_dtype == "bfloat16" else jnp.float32
            )

            def acc_fn(carry, mb):
                g_acc, loss_acc = carry
                (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(acc_dtype), g_acc, g
                )
                return (g_acc, loss_acc + loss), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params
            )
            (grads, loss), _ = jax.lax.scan(acc_fn, (g0, 0.0), micro)
            grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
            loss = loss / accum
            out = {"loss": loss}

        new_params, new_opt = opt.update(grads, state["opt"], params, state["step"])
        metrics = {
            "loss": out["loss"] if accum == 1 else loss,
            "grad_norm": _global_norm(grads),
        }
        if accum == 1:
            metrics["main_loss"] = out["main_loss"]
            metrics["aux_loss"] = out["aux_loss"]
        return (
            {"params": new_params, "opt": new_opt, "step": state["step"] + 1},
            metrics,
        )

    return train_step


def _global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves)
    )
