"""Checkpointing: flat-key .npz save/restore of param/opt pytrees.

No orbax in this environment; this is a self-contained implementation with
the properties a real deployment needs: deterministic flat addressing,
dtype/shape manifest, atomic writes, and partial restore (e.g. params-only
from a train checkpoint for serving).
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Any

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "checkpoint_manifest"]

_SEP = "##"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for keypath, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_k(k) for k in keypath)
        flat[key] = np.asarray(leaf)
    return flat


def _k(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def save_checkpoint(path: str, tree: Any, step: int | None = None) -> None:
    """Atomic: write to tmp in the same dir, then rename."""
    flat = _flatten(tree)
    manifest = {
        "step": step,
        "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)} for k, v in flat.items()},
    }
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".", suffix=".tmp")
    os.close(fd)
    try:
        np.savez(tmp, __manifest__=json.dumps(manifest), **flat)
        os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)
    finally:
        for t in (tmp, tmp + ".npz"):
            if os.path.exists(t):
                os.remove(t)


def checkpoint_manifest(path: str) -> dict:
    with np.load(path, allow_pickle=False) as z:
        return json.loads(str(z["__manifest__"]))


def restore_checkpoint(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  Missing keys raise; extra keys are ignored
    (partial restore)."""
    with np.load(path, allow_pickle=False) as z:
        flat_saved = {k: z[k] for k in z.files if k != "__manifest__"}
    leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for keypath, leaf in leaves:
        key = _SEP.join(_k(k) for k in keypath)
        if key not in flat_saved:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat_saved[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != expected {leaf.shape}")
        out.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
