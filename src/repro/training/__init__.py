"""repro.training — see module docstrings."""
