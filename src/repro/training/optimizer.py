"""Optimizers (pure JAX — no optax in this environment): AdamW, Adafactor.

Adafactor (factored second moments, no first moment) is the default above
~30B params: AdamW's 8 bytes/param of fp32 state does not fit 16 GB/chip at
512 chips for the 671B config (DESIGN.md Sec. 5).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "adamw", "adafactor", "make_optimizer", "cosine_schedule"]

Params = Any


class Optimizer(NamedTuple):
    init: Callable[[Params], Any]
    update: Callable[[Params, Any, Params, jax.Array], tuple[Params, Any]]
    # update(grads, state, params, step) -> (new_params, new_state)


def cosine_schedule(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)

    return lr


def adamw(
    lr: Callable | float = 3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {
            "m": jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "v": jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        }

    def update(grads, state, params, step):
        grads = _clip_by_global_norm(grads, grad_clip)
        stepf = step.astype(jnp.float32) + 1.0
        lr_t = lr_fn(step)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / (1 - b1**stepf)
            vhat = v / (1 - b2**stepf)
            delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m, v

        out = jax.tree_util.tree_map(upd, grads, state["m"], state["v"], params)
        new_params = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=_is3)
        new_m = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=_is3)
        new_v = jax.tree_util.tree_map(lambda t: t[2], out, is_leaf=_is3)
        return new_params, {"m": new_m, "v": new_v}

    return Optimizer(init, update)


def adafactor(
    lr: Callable | float = 1e-2,
    decay: float = 0.8,
    eps1: float = 1e-30,
    eps2: float = 1e-3,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
) -> Optimizer:
    """Shazeer & Stern 2018, factored second moments for >=2-D params."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        def st(p):
            if p.ndim >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros((*p.shape[:-2], p.shape[-1]), jnp.float32),
                }
            return {"v": jnp.zeros_like(p, jnp.float32)}

        return jax.tree_util.tree_map(st, params)

    def update(grads, state, params, step):
        stepf = step.astype(jnp.float32) + 1.0
        beta = 1.0 - stepf ** (-decay)
        lr_t = lr_fn(step)

        def upd(s, g, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps1
            if p.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = jnp.maximum(vr.mean(axis=-1, keepdims=True), eps1)
                u = g / jnp.sqrt(
                    (vr / denom)[..., None] * vc[..., None, :] + eps1
                )
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g / jnp.sqrt(v + eps1)
                new_s = {"v": v}
            # Update clipping (RMS <= clip_threshold).
            rms = jnp.sqrt(jnp.mean(u * u) + eps1)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            scale = jnp.maximum(eps2, _rms(p)) * lr_t
            newp = p.astype(jnp.float32) - scale * u
            if weight_decay:
                newp = newp - lr_t * weight_decay * p.astype(jnp.float32)
            return newp.astype(p.dtype), new_s

        # state's per-param dicts are the traversal leaves (is_leaf on the
        # first tree), grads/params align as array leaves underneath.
        out = jax.tree_util.tree_map(upd, state, grads, params, is_leaf=_state_leaf)
        new_params = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=_is2)
        new_state = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=_is2)
        return new_params, new_state

    return Optimizer(init, update)


def make_optimizer(name: str, lr=None, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(lr=lr or 3e-4, **kw)
    if name == "adafactor":
        return adafactor(lr=lr or 1e-2, **kw)
    raise ValueError(name)


# ----------------------------------------------------------------- helpers
def _rms(x):
    return jnp.sqrt(jnp.mean(jnp.square(x.astype(jnp.float32))))


def _clip_by_global_norm(grads, max_norm: float):
    if not max_norm:
        return grads
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree_util.tree_map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads)


def _is3(x):
    return isinstance(x, tuple) and len(x) == 3


def _is2(x):
    return isinstance(x, tuple) and len(x) == 2


def _state_leaf(x):
    return isinstance(x, dict) and ("v" in x or "vr" in x)
