"""Pure-jnp oracles for every Pallas kernel (the allclose references).

These are *definitions*, written for clarity not speed; tests sweep shapes
and dtypes asserting the kernels (interpret=True on CPU) match them.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "entropy_exit_ref",
    "entropy_exit_argmax_ref",
    "entropy_exit_argmax_heads_ref",
    "flash_decode_ref",
    "ssd_scan_ref",
    "ssd_update_ref",
]


def entropy_exit_ref(
    logits: jax.Array, threshold: float
) -> tuple[jax.Array, jax.Array]:
    """Normalized softmax entropy over the last axis + exit decision.

    Returns (entropy (B,), exit (B,) bool).  fp32 math, H normalized by
    log of the logits *width* (pad lanes included) — the same base the
    serving exit threshold uses (core.calibration.normalized_entropy).
    """
    lf = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(lf, axis=-1)
    h = -jnp.sum(jnp.exp(logp) * logp, axis=-1) / np.log(lf.shape[-1])
    return h, h < threshold


def entropy_exit_argmax_ref(
    logits: jax.Array, threshold: float
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused exit decision: (entropy (B,), exit (B,) bool, argmax (B,) i32).

    The argmax is jnp.argmax over the raw logits (first occurrence on
    ties) — exactly the token the serving jnp path emits at a branch exit.
    """
    h, ex = entropy_exit_ref(logits, threshold)
    return h, ex, jnp.argmax(logits, axis=-1).astype(jnp.int32)


def entropy_exit_argmax_heads_ref(
    logits: jax.Array,  # (K, B, V) stacked branch-head logits
    thresholds: jax.Array | float,  # scalar or (K,) per-head thresholds
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Multi-head fused exit decision over batched-head logits: per head
    exactly :func:`entropy_exit_argmax_ref` on ``logits[k]`` against
    ``thresholds[k]`` (a scalar threshold broadcasts to every head).
    Returns (entropy (K, B), exit (K, B) bool, argmax (K, B) int32)."""
    k = logits.shape[0]
    th = jnp.broadcast_to(jnp.asarray(thresholds, jnp.float32).reshape(-1), (k,))
    lf = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(lf, axis=-1)
    h = -jnp.sum(jnp.exp(logp) * logp, axis=-1) / np.log(lf.shape[-1])
    return h, h < th[:, None], jnp.argmax(logits, axis=-1).astype(jnp.int32)


def flash_decode_ref(
    q: jax.Array,  # (B, H, D)
    k: jax.Array,  # (Bc, C, K, D)
    v: jax.Array,  # (Bc, C, K, D)
    k_pos: jax.Array,  # (C,) shared or (Bc, C) per-sequence, -1 = empty slot
    q_pos: jax.Array,  # () shared or (B,) per-query-row, int32
    rows: jax.Array | None = None,  # (B,) int32: query row -> cache row
    window: int = 0,
) -> jax.Array:
    """Single-token GQA decode attention with (per-sequence) slot validity,
    optional sliding window, an optional survivor row map into a larger
    resident cache, and (continuous batching) per-query-row positions.
    Returns (B, H, D) in q.dtype."""
    b, h, d = q.shape
    if rows is not None:
        k, v = k[rows], v[rows]
        if k_pos.ndim == 2:
            k_pos = k_pos[rows]
    kh = k.shape[2]
    g = h // kh
    q_pos = jnp.broadcast_to(q_pos, (b,))[:, None]  # (B, 1) vs k_pos's (.., C)
    if k_pos.ndim == 1:
        k_pos = k_pos[None, :]
    qf = q.reshape(b, kh, g, d).astype(jnp.float32) / np.sqrt(d)
    s = jnp.einsum("bkgd,bckd->bkgc", qf, k.astype(jnp.float32))
    valid = (k_pos >= 0) & (k_pos <= q_pos)
    if window > 0:
        valid &= q_pos - k_pos < window
    valid = valid[:, None, None, :]
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgc,bckd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(b, h, d).astype(q.dtype)


def ssd_scan_ref(
    x: jax.Array,  # (B, L, H, P)  dt-scaled inputs
    a: jax.Array,  # (B, L, H)     per-step log decay (negative)
    b_mat: jax.Array,  # (B, L, H, N)
    c_mat: jax.Array,  # (B, L, H, N)
    h0: jax.Array | None = None,  # (B, H, P, N)
) -> tuple[jax.Array, jax.Array]:
    """Sequential SSM recurrence (the semantic definition of SSD):
        h_t = exp(a_t) h_{t-1} + x_t (x) B_t ;  y_t = h_t . C_t
    Returns (y (B,L,H,P), final h (B,H,P,N)).  fp32 math."""
    bsz, l, h, p = x.shape
    n = b_mat.shape[-1]
    xf = x.astype(jnp.float32)
    af = a.astype(jnp.float32)
    bf = b_mat.astype(jnp.float32)
    cf = c_mat.astype(jnp.float32)

    def step(hs, t):
        hn = hs * jnp.exp(af[:, t])[..., None, None] + jnp.einsum(
            "bhp,bhn->bhpn", xf[:, t], bf[:, t]
        )
        y = jnp.einsum("bhpn,bhn->bhp", hn, cf[:, t])
        return hn, y

    hinit = (
        h0.astype(jnp.float32)
        if h0 is not None
        else jnp.zeros((bsz, h, p, n), jnp.float32)
    )
    hlast, ys = jax.lax.scan(step, hinit, jnp.arange(l))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), hlast


def ssd_update_ref(
    h_state: jax.Array,  # (Bc, H, P, N) full-batch resident state
    x: jax.Array,  # (B, H, P)  dt-scaled input
    a: jax.Array,  # (B, H)     dt * A (negative)
    b_vec: jax.Array,  # (B, G, N)
    c_vec: jax.Array,  # (B, G, N)
    rows: jax.Array | None = None,  # (B,) int32 sub-batch row -> state row
) -> tuple[jax.Array, jax.Array]:
    """One recurrent SSD decode step: h' = e^a h + x (x) B ; y = h' . C,
    with an optional survivor row map into a larger resident state.
    Returns (y (B,H,P) fp32, new state rows (B,H,P,N) fp32), sub-batch
    order (the caller scatters the rows back)."""
    h = h_state if rows is None else h_state[rows]
    bsz, nh, p, n = h.shape
    g = b_vec.shape[1]
    rep = nh // g
    bh = jnp.repeat(b_vec, rep, axis=1).astype(jnp.float32)  # (B,H,N)
    ch = jnp.repeat(c_vec, rep, axis=1).astype(jnp.float32)
    h_new = h.astype(jnp.float32) * jnp.exp(a.astype(jnp.float32))[
        ..., None, None
    ] + jnp.einsum("bhp,bhn->bhpn", x.astype(jnp.float32), bh)
    y = jnp.einsum("bhpn,bhn->bhp", h_new, ch)
    return y, h_new
