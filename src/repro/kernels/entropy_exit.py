"""Fused softmax-entropy + exit-decision Pallas TPU kernels.

The BranchyNet confidence test (paper Sec. III) runs at every side branch
for every decoded token: H(softmax(logits)) / log V < threshold.  At Qwen3's
151 936-token vocab the naive lowering materializes log_softmax (B, V) in
fp32 — 3 HBM round trips.  These kernels stream the vocab once through VMEM
with an online (max, sum-exp, sum-l*exp) accumulator and emit only (B,)
entropy + exit flags:

    H = lse - (sum_i l_i e^{l_i - m}) / (sum_i e^{l_i - m}),  lse = m + log s

Normalization contract: H is divided by log(V) with V the *width of the
logits array* — exactly what the serving exit threshold compares against
(``core.calibration.normalized_entropy`` divides by ``log(logits.shape[-1])``
too, so padded-vocab configs, whose pad lanes carry -1e30 and contribute 0
to every accumulator, agree between the inline jnp path and the kernel).

``entropy_exit_argmax_pallas`` additionally carries an online (best value,
best index) pair so the branch's exit *token* comes out of the same single
pass — the serving fast path never materializes a separate softmax or
argmax over (B, V).  Tie-breaking matches ``jnp.argmax`` (first occurrence:
strictly-greater updates across tiles, first-index argmax within a tile),
so the emitted token is bitwise identical to the jnp path.

Grid: (B_tiles, V_tiles); the V dim is the sequential inner loop, carrying
the accumulators in VMEM scratch, finalizing on the last tile.  BlockSpec
tiles are (block_b, block_v) with block_v a multiple of 128 (lane width)
and block_b a multiple of 8 (sublane) — MXU is not involved; these are VPU
reduction kernels.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "entropy_exit_pallas",
    "entropy_exit_argmax_pallas",
    "entropy_exit_argmax_heads_pallas",
]

NEG_INF = -1e30


def _kernel(
    logits_ref,  # (block_b, block_v) VMEM
    thresh_ref,  # (1, 1) SMEM
    h_ref,  # (block_b,) out
    exit_ref,  # (block_b,) out
    m_scr,  # (block_b,) VMEM scratch: running max
    s_scr,  # (block_b,) running sum exp
    u_scr,  # (block_b,) running sum l * exp
    *,
    num_v_blocks: int,
    vocab: int,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        s_scr[...] = jnp.zeros_like(s_scr)
        u_scr[...] = jnp.zeros_like(u_scr)

    l = logits_ref[...].astype(jnp.float32)  # (bb, bv)
    m_old = m_scr[...]
    m_new = jnp.maximum(m_old, l.max(axis=-1))
    corr = jnp.exp(m_old - m_new)
    e = jnp.exp(l - m_new[:, None])
    s_scr[...] = s_scr[...] * corr + e.sum(axis=-1)
    u_scr[...] = u_scr[...] * corr + (l * e).sum(axis=-1)
    m_scr[...] = m_new

    @pl.when(j == num_v_blocks - 1)
    def _finalize():
        s = s_scr[...]
        lse = m_scr[...] + jnp.log(s)
        h = (lse - u_scr[...] / s) / np.log(vocab)
        h_ref[...] = h
        exit_ref[...] = h < thresh_ref[0, 0]


@functools.partial(jax.jit, static_argnames=("block_b", "block_v", "interpret"))
def entropy_exit_pallas(
    logits: jax.Array,  # (B, V)
    threshold: jax.Array | float,
    *,
    block_b: int = 8,
    block_v: int = 2048,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (normalized entropy (B,), exit flags (B,) bool)."""
    b, v = logits.shape
    vocab = v
    # Pad: batch to block_b; vocab to block_v with -inf (exact no-ops in the
    # online accumulator: e^{-inf} = 0).
    pb = (-b) % block_b
    pv = (-v) % block_v
    if pb or pv:
        logits = jnp.pad(logits, ((0, pb), (0, pv)), constant_values=NEG_INF)
    bb, vv = logits.shape
    grid = (bb // block_b, vv // block_v)

    thresh = jnp.asarray(threshold, jnp.float32).reshape(1, 1)
    h, ex = pl.pallas_call(
        functools.partial(_kernel, num_v_blocks=grid[1], vocab=vocab),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_v), lambda i, j: (i, j)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((block_b,), lambda i, j: (i,)),
            pl.BlockSpec((block_b,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bb,), jnp.float32),
            jax.ShapeDtypeStruct((bb,), jnp.bool_),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_b,), jnp.float32),
            pltpu.VMEM((block_b,), jnp.float32),
            pltpu.VMEM((block_b,), jnp.float32),
        ],
        interpret=interpret,
    )(logits, thresh)
    return h[:b], ex[:b]


def _kernel_argmax(
    logits_ref,  # (block_b, block_v) VMEM
    thresh_ref,  # (1, 1) SMEM
    h_ref,  # (block_b,) out
    exit_ref,  # (block_b,) out
    idx_ref,  # (block_b,) int32 out
    m_scr,  # (block_b,) VMEM scratch: running max
    s_scr,  # (block_b,) running sum exp
    u_scr,  # (block_b,) running sum l * exp
    bv_scr,  # (block_b,) running best value
    bi_scr,  # (block_b,) int32 running best index
    *,
    num_v_blocks: int,
    block_v: int,
    vocab: int,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        s_scr[...] = jnp.zeros_like(s_scr)
        u_scr[...] = jnp.zeros_like(u_scr)
        bv_scr[...] = jnp.full_like(bv_scr, NEG_INF)
        bi_scr[...] = jnp.zeros_like(bi_scr)

    l = logits_ref[...].astype(jnp.float32)  # (bb, bv)
    m_old = m_scr[...]
    m_new = jnp.maximum(m_old, l.max(axis=-1))
    corr = jnp.exp(m_old - m_new)
    e = jnp.exp(l - m_new[:, None])
    s_scr[...] = s_scr[...] * corr + e.sum(axis=-1)
    u_scr[...] = u_scr[...] * corr + (l * e).sum(axis=-1)
    m_scr[...] = m_new

    # Online argmax: first occurrence within the tile (jnp.argmax), and a
    # strictly-greater update across tiles, reproduce jnp.argmax over the
    # full row exactly (comparisons are exact; no float error involved).
    loc_v = l.max(axis=-1)
    loc_i = jnp.argmax(l, axis=-1).astype(jnp.int32) + j * block_v
    upd = loc_v > bv_scr[...]
    bv_scr[...] = jnp.where(upd, loc_v, bv_scr[...])
    bi_scr[...] = jnp.where(upd, loc_i, bi_scr[...])

    @pl.when(j == num_v_blocks - 1)
    def _finalize():
        s = s_scr[...]
        lse = m_scr[...] + jnp.log(s)
        h = (lse - u_scr[...] / s) / np.log(vocab)
        h_ref[...] = h
        exit_ref[...] = h < thresh_ref[0, 0]
        idx_ref[...] = bi_scr[...]


@functools.partial(jax.jit, static_argnames=("block_b", "block_v", "interpret"))
def entropy_exit_argmax_pallas(
    logits: jax.Array,  # (B, V)
    threshold: jax.Array | float,
    *,
    block_b: int = 8,
    block_v: int = 2048,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused exit decision: one pass over (B, V) logits returns
    (normalized entropy (B,), exit flags (B,) bool, argmax token (B,) int32).
    """
    b, v = logits.shape
    vocab = v
    pb = (-b) % block_b
    pv = (-v) % block_v
    if pb or pv:
        logits = jnp.pad(logits, ((0, pb), (0, pv)), constant_values=NEG_INF)
    bb, vv = logits.shape
    grid = (bb // block_b, vv // block_v)

    thresh = jnp.asarray(threshold, jnp.float32).reshape(1, 1)
    h, ex, idx = pl.pallas_call(
        functools.partial(
            _kernel_argmax, num_v_blocks=grid[1], block_v=block_v, vocab=vocab
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_v), lambda i, j: (i, j)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((block_b,), lambda i, j: (i,)),
            pl.BlockSpec((block_b,), lambda i, j: (i,)),
            pl.BlockSpec((block_b,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bb,), jnp.float32),
            jax.ShapeDtypeStruct((bb,), jnp.bool_),
            jax.ShapeDtypeStruct((bb,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_b,), jnp.float32),
            pltpu.VMEM((block_b,), jnp.float32),
            pltpu.VMEM((block_b,), jnp.float32),
            pltpu.VMEM((block_b,), jnp.float32),
            pltpu.VMEM((block_b,), jnp.int32),
        ],
        interpret=interpret,
    )(logits, thresh)
    return h[:b], ex[:b], idx[:b]


def _kernel_argmax_heads(
    logits_ref,  # (1, block_b, block_v) VMEM — one head's (B, V) tile
    thresh_ref,  # (K, 1) SMEM — per-head exit thresholds
    h_ref,  # (1, block_b) out
    exit_ref,  # (1, block_b) out
    idx_ref,  # (1, block_b) int32 out
    m_scr,  # (block_b,) VMEM scratch: running max
    s_scr,  # (block_b,) running sum exp
    u_scr,  # (block_b,) running sum l * exp
    bv_scr,  # (block_b,) running best value
    bi_scr,  # (block_b,) int32 running best index
    *,
    num_v_blocks: int,
    block_v: int,
    vocab: int,
):
    k = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        s_scr[...] = jnp.zeros_like(s_scr)
        u_scr[...] = jnp.zeros_like(u_scr)
        bv_scr[...] = jnp.full_like(bv_scr, NEG_INF)
        bi_scr[...] = jnp.zeros_like(bi_scr)

    l = logits_ref[0].astype(jnp.float32)  # (bb, bv)
    m_old = m_scr[...]
    m_new = jnp.maximum(m_old, l.max(axis=-1))
    corr = jnp.exp(m_old - m_new)
    e = jnp.exp(l - m_new[:, None])
    s_scr[...] = s_scr[...] * corr + e.sum(axis=-1)
    u_scr[...] = u_scr[...] * corr + (l * e).sum(axis=-1)
    m_scr[...] = m_new

    loc_v = l.max(axis=-1)
    loc_i = jnp.argmax(l, axis=-1).astype(jnp.int32) + j * block_v
    upd = loc_v > bv_scr[...]
    bv_scr[...] = jnp.where(upd, loc_v, bv_scr[...])
    bi_scr[...] = jnp.where(upd, loc_i, bi_scr[...])

    @pl.when(j == num_v_blocks - 1)
    def _finalize():
        s = s_scr[...]
        lse = m_scr[...] + jnp.log(s)
        h = (lse - u_scr[...] / s) / np.log(vocab)
        h_ref[0] = h
        exit_ref[0] = h < thresh_ref[k, 0]
        idx_ref[0] = bi_scr[...]


@functools.partial(jax.jit, static_argnames=("block_b", "block_v", "interpret"))
def entropy_exit_argmax_heads_pallas(
    logits: jax.Array,  # (K, B, V) stacked branch-head logits
    thresholds: jax.Array | float,  # scalar or (K,) per-head thresholds
    *,
    block_b: int = 8,
    block_v: int = 2048,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Multi-head fused exit decision: ONE launch over the batched-head
    (K, B, V) logits returns (normalized entropy (K, B), exit flags (K, B)
    bool, argmax token (K, B) int32).

    The grid gains a leading K dim over the single-head kernel — heads are
    independent rows of the same streaming reduction, so each (k, i) row
    group carries its own accumulator through the sequential V loop and
    the per-head slice is bitwise identical to ``entropy_exit_argmax_pallas``
    on ``logits[k]``.  Per-head thresholds sit in SMEM ((K, 1), scalar
    broadcast to every head), so K heads with K different calibration
    points still fuse into the single launch.
    """
    k, b, v = logits.shape
    vocab = v
    pb = (-b) % block_b
    pv = (-v) % block_v
    if pb or pv:
        logits = jnp.pad(
            logits, ((0, 0), (0, pb), (0, pv)), constant_values=NEG_INF
        )
    _, bb, vv = logits.shape
    grid = (k, bb // block_b, vv // block_v)

    thresh = jnp.broadcast_to(
        jnp.asarray(thresholds, jnp.float32).reshape(-1, 1), (k, 1)
    )
    h, ex, idx = pl.pallas_call(
        functools.partial(
            _kernel_argmax_heads,
            num_v_blocks=grid[2], block_v=block_v, vocab=vocab,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_b, block_v), lambda k, i, j: (k, i, j)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((1, block_b), lambda k, i, j: (k, i)),
            pl.BlockSpec((1, block_b), lambda k, i, j: (k, i)),
            pl.BlockSpec((1, block_b), lambda k, i, j: (k, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, bb), jnp.float32),
            jax.ShapeDtypeStruct((k, bb), jnp.bool_),
            jax.ShapeDtypeStruct((k, bb), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_b,), jnp.float32),
            pltpu.VMEM((block_b,), jnp.float32),
            pltpu.VMEM((block_b,), jnp.float32),
            pltpu.VMEM((block_b,), jnp.float32),
            pltpu.VMEM((block_b,), jnp.int32),
        ],
        interpret=interpret,
    )(logits, thresh)
    return h[:, :b], ex[:, :b], idx[:, :b]
