"""Mamba2 SSD chunked-scan Pallas TPU kernel.

TPU adaptation of the Mamba2 GPU kernel (DESIGN.md: no warp-level scan on
TPU — instead the chunk-local work is cast as (chunk x chunk) decay matmuls
that run on the MXU, and the only sequential dependency is the tiny
(P x N) state carried across chunk tiles in VMEM scratch):

    per chunk:  Y_diag = ((C B^T) o exp(segsum(a))) X
                Y_off  = exp(cumsum(a)) * (C h_prev^T)
                h_new  = exp(sum a) h_prev + X^T (exp(sum a - cumsum a) o B)

Grid: (B, H, n_chunks), chunk dim sequential (carries h in scratch).
Block tiles: x (1, chunk, 1, P), B/C (1, chunk, 1, N) — P, N are multiples
of the 128 lane width for the assigned configs (P=64 pads to 128 via the
wrapper when needed).

``ssd_update_pallas`` is the decode-time sibling: one recurrent step
``h' = e^a h + x (x) B ; y = h' . C`` per (batch, head).  Like the
flash_decode kernel it takes a scalar-prefetched survivor row map so a
compacted sub-batch reads its rows of the full-batch resident SSM state
copy-free; the updated rows come back dense and the model scatters them in
place (``.at[rows].set(mode="drop")``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan_pallas", "ssd_update_pallas"]


def _kernel(
    x_ref,  # (1, L, 1, P)
    a_ref,  # (1, L, 1)
    b_ref,  # (1, L, 1, N)
    c_ref,  # (1, L, 1, N)
    y_ref,  # (1, L, 1, P) out
    hout_ref,  # (1, 1, P, N) out (final state)
    h_scr,  # (P, N) scratch fp32
    *,
    num_chunks: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # (L, P)
    a = a_ref[0, :, 0].astype(jnp.float32)  # (L,)
    bm = b_ref[0, :, 0, :].astype(jnp.float32)  # (L, N)
    cm = c_ref[0, :, 0, :].astype(jnp.float32)  # (L, N)
    l = x.shape[0]

    a_cum = jnp.cumsum(a)  # inclusive
    # decay[i, j] = exp(sum_{k=j+1..i} a_k) for i >= j.
    diff = a_cum[:, None] - a_cum[None, :]
    row = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    decay = jnp.where(row >= col, jnp.exp(diff), 0.0)

    scores = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (L, L)
    y_diag = jax.lax.dot_general(
        scores * decay, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (L, P)

    h_prev = h_scr[...]
    in_decay = jnp.exp(a_cum)  # (L,)
    y_off = in_decay[:, None] * jax.lax.dot_general(
        cm, h_prev, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (L, N) x (P, N)^T -> (L, P)

    y_ref[0, :, 0, :] = (y_diag + y_off).astype(y_ref.dtype)

    # State update.
    to_end = jnp.exp(a_cum[-1] - a_cum)  # (L,)
    states = jax.lax.dot_general(
        x, bm * to_end[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (P, N)
    h_scr[...] = h_prev * jnp.exp(a_cum[-1]) + states

    @pl.when(ci == num_chunks - 1)
    def _final():
        hout_ref[0, 0] = h_scr[...].astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(
    x: jax.Array,  # (B, L, H, P)  dt-scaled inputs
    a: jax.Array,  # (B, L, H)     log decays
    b_mat: jax.Array,  # (B, L, H, N)
    c_mat: jax.Array,  # (B, L, H, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B,L,H,P), final state (B,H,P,N))."""
    bsz, l, h, p = x.shape
    n = b_mat.shape[-1]
    pad = (-l) % chunk
    if pad:
        # a=0 (decay 1) and x=0 keep the padded tail a state no-op.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    ll = x.shape[1]
    nc = ll // chunk

    y, h_final = pl.pallas_call(
        functools.partial(_kernel, num_chunks=nc),
        grid=(bsz, h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda i, j, c_: (i, c_, j, 0)),
            pl.BlockSpec((1, chunk, 1), lambda i, j, c_: (i, c_, j)),
            pl.BlockSpec((1, chunk, 1, n), lambda i, j, c_: (i, c_, j, 0)),
            pl.BlockSpec((1, chunk, 1, n), lambda i, j, c_: (i, c_, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda i, j, c_: (i, c_, j, 0)),
            pl.BlockSpec((1, 1, p, n), lambda i, j, c_: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, ll, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, a, b_mat, c_mat)
    return y[:, :l], h_final


def _update_kernel(
    rows_ref,  # (B,) SMEM scalar-prefetch: sub-batch row -> state row
    h_ref,  # (1, 1, P, N)   resident state row
    x_ref,  # (1, 1, P)
    a_ref,  # (1, 1)
    b_ref,  # (1, 1, N)
    c_ref,  # (1, 1, N)
    y_ref,  # (1, 1, P) out
    hout_ref,  # (1, 1, P, N) out (updated state row, dense order)
):
    h_prev = h_ref[0, 0].astype(jnp.float32)  # (P, N)
    x = x_ref[0, 0].astype(jnp.float32)  # (P,)
    a = a_ref[0, 0].astype(jnp.float32)  # ()
    bv = b_ref[0, 0].astype(jnp.float32)  # (N,)
    cv = c_ref[0, 0].astype(jnp.float32)  # (N,)

    h_new = h_prev * jnp.exp(a) + x[:, None] * bv[None, :]  # (P, N)
    y = jnp.sum(h_new * cv[None, :], axis=-1)  # (P,)
    y_ref[0, 0] = y.astype(y_ref.dtype)
    hout_ref[0, 0] = h_new.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_update_pallas(
    h_state: jax.Array,  # (Bc, H, P, N) full-batch resident SSM state
    x: jax.Array,  # (B, H, P)  dt-scaled input, B <= Bc
    a: jax.Array,  # (B, H)     dt * A (negative)
    b_vec: jax.Array,  # (B, G, N)
    c_vec: jax.Array,  # (B, G, N)
    rows: jax.Array | None = None,  # (B,) int32 sub-batch row -> state row
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """One recurrent SSD decode step against the resident state.

    Returns (y (B, H, P) fp32, new state rows (B, H, P, N) fp32) in the
    *sub-batch* order — the caller scatters the state rows back.  ``rows``
    is a scalar-prefetch operand: the block index maps DMA only the
    survivor rows of the full state, no gather copy.
    """
    b, h, p = x.shape
    g, n = b_vec.shape[1], b_vec.shape[2]
    rep = h // g  # heads per B/C group
    if rows is None:
        rows = jnp.arange(b, dtype=jnp.int32)
    rows = rows.astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1, 1, p, n), lambda i, j, rows_: (rows_[i], j, 0, 0)),
            pl.BlockSpec((1, 1, p), lambda i, j, rows_: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j, rows_: (i, j)),
            pl.BlockSpec((1, 1, n), lambda i, j, rows_: (i, j // rep, 0)),
            pl.BlockSpec((1, 1, n), lambda i, j, rows_: (i, j // rep, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, p), lambda i, j, rows_: (i, j, 0)),
            pl.BlockSpec((1, 1, p, n), lambda i, j, rows_: (i, j, 0, 0)),
        ],
    )
    y, h_new = pl.pallas_call(
        _update_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, h, p), jnp.float32),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(rows, h_state, x, a, b_vec, c_vec)
    return y, h_new
