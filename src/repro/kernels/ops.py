"""jit'd public wrappers for the Pallas kernels.

On a real TPU these dispatch to the compiled kernels; on CPU (this
container) they run in interpret mode, which executes the kernel body in
Python — correct but slow, so the model code uses the pure-jnp paths by
default and these wrappers are exercised by tests/benchmarks and are the
drop-in used on hardware (``use_kernels=True`` plumbing).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.entropy_exit import entropy_exit_pallas
from repro.kernels.flash_decode import flash_decode_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas

__all__ = ["entropy_exit", "flash_decode", "ssd_scan", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def entropy_exit(logits, threshold, *, interpret: bool | None = None):
    """(B, V) logits -> (normalized entropy (B,), exit flags (B,))."""
    interp = (not on_tpu()) if interpret is None else interpret
    return entropy_exit_pallas(logits, threshold, interpret=interp)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def flash_decode(q, k, v, k_pos, q_pos, rows=None, *, window: int = 0,
                 interpret: bool | None = None):
    """Single-token GQA decode attention against a (ring) KV cache.
    ``rows`` maps a compacted survivor sub-batch onto cache rows."""
    interp = (not on_tpu()) if interpret is None else interpret
    return flash_decode_pallas(q, k, v, k_pos, q_pos, rows, window=window,
                               interpret=interp)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, a, b_mat, c_mat, *, chunk: int = 128,
             interpret: bool | None = None):
    """Mamba2 chunked SSD scan: (y, final_state)."""
    interp = (not on_tpu()) if interpret is None else interpret
    return ssd_scan_pallas(x, a, b_mat, c_mat, chunk=chunk, interpret=interp)
