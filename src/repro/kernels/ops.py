"""jit'd public wrappers for the Pallas kernels — the decode hot path's
kernel dispatch layer.

These are what the model/serving code calls when ``use_kernels`` is on:

  * :func:`flash_decode` — single-token GQA decode attention streaming
    survivor rows straight out of the full-batch resident KV cache via a
    scalar-prefetched row map (``models.attention.attn_apply`` decode);
  * :func:`entropy_exit_argmax` — the fused BranchyNet exit decision:
    normalized entropy, threshold flag and argmax token in ONE pass over
    the (B, V) branch logits (``serving.tiers.TierExecutor`` per-branch
    exit masking);
  * :func:`entropy_exit` — the entropy + flag pair without the token
    (calibration sweeps);
  * :func:`ssd_update` — one recurrent Mamba2/SSD decode step against the
    resident state, same ``rows`` plumbing (``models.mamba.mamba_apply``
    decode);
  * :func:`ssd_scan` — the chunked SSD prefill/train scan.

``use_kernels`` resolution (:func:`resolve_use_kernels`): ``None`` means
auto — kernels on TPU, pure-jnp elsewhere.  An explicit ``True`` off-TPU
runs the kernels in *interpret mode* (the kernel body executes as jax ops
on CPU): bit-for-bit the same dataflow the TPU lowering compiles, correct
but slow, which is exactly what the equivalence tests and the
``benchmarks/kernel_micro.py`` sweep exercise.  Each wrapper picks
interpret mode automatically from the backend; pass ``interpret=``
explicitly to override.

All wrappers are shape-polymorphic the cheap way: they are ``jax.jit``-ed
(and re-traced inside the tier runtime's per-(spec, bucket) segment
cache), so a new *bucket* shape compiles once and a survivor-count change
within a bucket never recompiles — the same contract as the jnp path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.entropy_exit import (
    entropy_exit_argmax_heads_pallas,
    entropy_exit_argmax_pallas,
    entropy_exit_pallas,
)
from repro.kernels.flash_decode import flash_decode_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas, ssd_update_pallas

__all__ = [
    "entropy_exit",
    "entropy_exit_argmax",
    "entropy_exit_argmax_heads",
    "flash_decode",
    "ssd_scan",
    "ssd_update",
    "on_tpu",
    "resolve_use_kernels",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_use_kernels(flag: bool | None, *, sharded: bool = False) -> bool:
    """The ``use_kernels`` tri-state: None = auto (kernels on TPU only),
    True/False force the kernel / pure-jnp path (True off-TPU runs the
    kernels in interpret mode).

    ``sharded=True`` (a mesh-sharded tier segment) always resolves to the
    jnp path: the Pallas kernels are single-device programs, and handing
    them a mesh-global batch under SPMD would either fail to partition or
    silently gather the full sharded KV cache to one device.  The jnp
    lowering partitions cleanly under ``NamedSharding``; a per-shard
    ``shard_map`` kernel dispatch is the documented follow-up."""
    if sharded:
        return False
    return on_tpu() if flag is None else bool(flag)


@functools.partial(jax.jit, static_argnames=("interpret",))
def entropy_exit(logits, threshold, *, interpret: bool | None = None):
    """(B, V) logits -> (normalized entropy (B,), exit flags (B,))."""
    interp = (not on_tpu()) if interpret is None else interpret
    return entropy_exit_pallas(logits, threshold, interpret=interp)


@functools.partial(jax.jit, static_argnames=("interpret",))
def entropy_exit_argmax(logits, threshold, *, interpret: bool | None = None):
    """Fused exit decision: (B, V) logits -> (normalized entropy (B,),
    exit flags (B,), argmax token (B,) int32) in one streaming pass."""
    interp = (not on_tpu()) if interpret is None else interpret
    return entropy_exit_argmax_pallas(logits, threshold, interpret=interp)


@functools.partial(jax.jit, static_argnames=("interpret",))
def entropy_exit_argmax_heads(logits, thresholds, *,
                              interpret: bool | None = None):
    """Multi-head fused exit decision: (K, B, V) stacked branch-head
    logits -> (normalized entropy (K, B), exit flags (K, B), argmax token
    (K, B) int32) in ONE kernel launch — the batched-head counterpart of
    :func:`entropy_exit_argmax` (per-head slices are bitwise identical).
    ``thresholds`` is a scalar (every head) or (K,) per-head array.
    Sharded segments never reach this wrapper: ``resolve_use_kernels``
    routes them to the jnp fallback (see ``serving.tiers``)."""
    interp = (not on_tpu()) if interpret is None else interpret
    return entropy_exit_argmax_heads_pallas(logits, thresholds,
                                            interpret=interp)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def flash_decode(q, k, v, k_pos, q_pos, rows=None, *, window: int = 0,
                 interpret: bool | None = None):
    """Single-token GQA decode attention against a (ring) KV cache.
    ``rows`` maps a compacted survivor sub-batch onto cache rows."""
    interp = (not on_tpu()) if interpret is None else interpret
    return flash_decode_pallas(q, k, v, k_pos, q_pos, rows, window=window,
                               interpret=interp)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, a, b_mat, c_mat, *, chunk: int = 128,
             interpret: bool | None = None):
    """Mamba2 chunked SSD scan: (y, final_state)."""
    interp = (not on_tpu()) if interpret is None else interpret
    return ssd_scan_pallas(x, a, b_mat, c_mat, chunk=chunk, interpret=interp)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_update(h_state, x, a, b_vec, c_vec, rows=None, *,
               interpret: bool | None = None):
    """One recurrent SSD decode step against the full-batch resident state;
    ``rows`` maps the sub-batch onto state rows (scalar-prefetch, no gather
    copy).  Returns (y (B,H,P), new state rows (B,H,P,N)), fp32."""
    interp = (not on_tpu()) if interpret is None else interpret
    return ssd_update_pallas(h_state, x, a, b_vec, c_vec, rows,
                             interpret=interp)
