"""repro.kernels — Pallas TPU kernel suite for the decode hot path.

    flash_decode.py   single-token GQA decode attention; scalar-prefetch
                      survivor row map into the resident KV cache
    entropy_exit.py   streaming softmax-entropy exit test; fused
                      entropy + flag + argmax-token variant
    ssd_scan.py       Mamba2 chunked SSD scan + single-step ssd_update
                      (same survivor row map into the resident state)
    ref.py            pure-jnp oracles (the allclose references)
    ops.py            jit'd dispatch wrappers + `use_kernels` resolution

Serving reaches these through ``ops`` behind the ``use_kernels`` knob
(auto: on TPU); off-TPU the kernels run in interpret mode for the
equivalence tests and `make bench-kernels`.
"""
