"""Single-token GQA decode attention Pallas TPU kernel (flash-decode).

The decode hot loop attends one query against a (ring) KV cache of up to
512k slots.  This kernel streams the cache through VMEM in ``block_c``-slot
tiles with the online-softmax accumulator, fusing slot-validity and
sliding-window masking (the paper's long-context serving path).

Layout: q (B, H, D) grouped as (B, K, G, D); cache (Bc, C, K, D) where the
cache batch Bc may exceed the query batch B.  Slot validity ``k_pos`` is
per sequence, (Bc, C) (a shared (C,) vector is broadcast by the wrapper):
the survivor-compacted tier runtime leaves holes (-1) in rows that skipped
a step downstream of their exit.

Survivor compaction: ``rows`` (B,) maps query row i -> cache row rows[i].
It is a *scalar-prefetch* operand (pltpu.PrefetchScalarGridSpec), so the
block index maps read it before the body runs and DMA only the survivor
rows of the full-batch cache — a dense sub-batch attends in-place against
the resident cache with zero gather copies.

Grid: (B, K, C_tiles) — the cache dim is the sequential inner loop; each
(batch, kv-head) pair owns its accumulator scratch.  Tiles are
(block_c, D) with D padded to the 128 lane width by the wrapper; the
score matmul (G x D) @ (D x block_c) runs on the MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_decode_pallas"]

NEG_INF = -1e30


def _kernel(
    rows_ref,  # (B,) SMEM scalar-prefetch: query row -> cache row
    qpos_ref,  # (B,) SMEM scalar-prefetch: per-query-row position
    q_ref,  # (1, 1, G, D)
    k_ref,  # (1, block_c, 1, D)
    v_ref,  # (1, block_c, 1, D)
    pos_ref,  # (1, block_c)  int32 per-sequence slot positions
    o_ref,  # (1, 1, G, D) out
    m_scr,  # (G,) scratch
    l_scr,  # (G,)
    acc_scr,  # (G, D)
    *,
    num_c_blocks: int,
    window: int,
    scale: float,
):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)  # (bc, D)
    v = v_ref[0, :, 0].astype(jnp.float32)  # (bc, D)
    kpos = pos_ref[0, :]  # (bc,)
    qpos = qpos_ref[pl.program_id(0)]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (G, bc)
    valid = (kpos >= 0) & (kpos <= qpos)
    if window > 0:
        valid &= qpos - kpos < window
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_old = m_scr[...]
    m_new = jnp.maximum(m_old, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_old - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(c == num_c_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "block_c", "interpret")
)
def flash_decode_pallas(
    q: jax.Array,  # (B, H, D)
    k: jax.Array,  # (Bc, C, K, D)
    v: jax.Array,  # (Bc, C, K, D)
    k_pos: jax.Array,  # (C,) shared or (Bc, C) per-sequence, int32
    q_pos: jax.Array,  # () shared or (B,) per-query-row, int32
    rows: jax.Array | None = None,  # (B,) int32 query row -> cache row
    *,
    window: int = 0,
    block_c: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, h, d = q.shape
    bc, c, kh, _ = k.shape
    g = h // kh
    scale = 1.0 / np.sqrt(d)

    if k_pos.ndim == 1:
        k_pos = jnp.broadcast_to(k_pos, (bc, c))
    if rows is None:
        rows = jnp.arange(b, dtype=jnp.int32)

    pc = (-c) % block_c
    if pc:
        k = jnp.pad(k, ((0, 0), (0, pc), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pc), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pc)), constant_values=-1)
    cc = k.shape[1]
    nc = cc // block_c

    qg = q.reshape(b, kh, g, d)
    # Per-row query positions (continuous batching) ride the same
    # scalar-prefetch path; a shared scalar broadcasts to every row.
    qpos = jnp.broadcast_to(q_pos.astype(jnp.int32), (b,))
    rows = rows.astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kh, nc),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda i, j, c_, rows_, qp_: (i, j, 0, 0)),
            pl.BlockSpec(
                (1, block_c, 1, d),
                lambda i, j, c_, rows_, qp_: (rows_[i], c_, j, 0),
            ),
            pl.BlockSpec(
                (1, block_c, 1, d),
                lambda i, j, c_, rows_, qp_: (rows_[i], c_, j, 0),
            ),
            pl.BlockSpec(
                (1, block_c), lambda i, j, c_, rows_, qp_: (rows_[i], c_)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, g, d), lambda i, j, c_, rows_, qp_: (i, j, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _kernel, num_c_blocks=nc, window=window, scale=scale
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kh, g, d), q.dtype),
        interpret=interpret,
    )(rows, qpos, qg, k, v, k_pos)
    return out.reshape(b, h, d)
