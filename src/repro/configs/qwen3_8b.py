"""Qwen3-8B [hf:Qwen/Qwen3-8B] — dense, GQA kv=8, qk_norm."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    arch_type="dense",
    source="hf:Qwen/Qwen3-8B",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151936,
    use_qk_norm=True,
    rope_theta=1_000_000.0,
    branch_layers=(9, 18, 27),
    grad_accum=16,
    decode_qhd_shard=True,  # §Perf pair 3: 5.8x decode step
    param_dtype="bfloat16",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=512,
        vocab_size=512,
        branch_layers=(1,),
        remat=False,
    )
