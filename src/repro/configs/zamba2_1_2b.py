"""Zamba2-1.2B [arXiv:2411.15242] — Mamba2 trunk + shared attention block."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    arch_type="hybrid",
    source="arXiv:2411.15242",
    num_layers=38,
    d_model=2048,
    num_heads=32,  # shared attention block
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,  # shared block MLP
    vocab_size=32000,
    ssm_state_dim=64,
    ssm_num_heads=64,  # 2*2048 / 64
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=64,
    attn_every=6,  # shared block after layers 6,12,...,36
    branch_layers=(9, 19, 29),
    grad_accum=4,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=256,
        ssm_state_dim=16,
        ssm_num_heads=4,
        ssm_chunk=16,
        vocab_size=512,
        attn_every=1,
        branch_layers=(1,),
        remat=False,
    )
