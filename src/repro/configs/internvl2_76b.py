"""InternVL2-76B [arXiv:2404.16821] — InternViT (stubbed) + InternLM2-76B
language decoder.  input_specs provides pre-projected patch embeddings."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    arch_type="vlm",
    source="arXiv:2404.16821",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    frontend="vision",
    num_patches=1024,
    branch_layers=(20, 40, 60),
    fsdp=True,
    fsdp_axes=("pod", "data"),
    optimizer="adafactor",
    grad_accum=8,  # §Perf pair 2: halves FSDP gather rounds
    seq_shard_activations=True,
    param_dtype="bfloat16",
    accum_dtype="bfloat16",
    decode_qhd_shard=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=512,
        vocab_size=512,
        num_patches=8,
        branch_layers=(1,),
        fsdp=False,
        grad_accum=1,
        remat=False,
    )
