"""DeepSeek-V3 671B [arXiv:2412.19437] — MLA + 1 shared / 256 routed top-8
MoE + MTP.  First 3 layers dense (d_ff 18432), remaining 58 MoE with
per-expert hidden 2048."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    source="arXiv:2412.19437",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,  # MLA ignores kv heads; kept for bookkeeping
    head_dim=128,
    d_ff=18432,  # dense first-k layers
    vocab_size=129280,
    use_mla=True,
    mla_kv_rank=512,
    mla_q_rank=1536,
    mla_rope_dim=64,
    num_experts=256,
    experts_per_token=8,
    num_shared_experts=1,
    moe_d_ff=2048,
    first_k_dense=3,
    use_mtp=True,
    branch_layers=(15, 30, 45),
    # 671B on 16 GB/chip: FSDP over the data axes + Adafactor + grad accum.
    fsdp=True,
    fsdp_axes=("pod", "data"),
    optimizer="adafactor",
    grad_accum=16,
    param_dtype="bfloat16",
    accum_dtype="bfloat16",
    moe_fsdp_dim="ff",  # §Perf pair 1: -8%% collective
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=512,
        vocab_size=512,
        mla_kv_rank=64,
        mla_q_rank=96,
        mla_rope_dim=16,
        num_experts=4,
        experts_per_token=2,
        num_shared_experts=1,
        moe_d_ff=128,
        first_k_dense=1,
        branch_layers=(1,),
        fsdp=False,
        grad_accum=1,
        remat=False,
    )
