"""Model/run configuration system.

One frozen dataclass describes every architecture family the framework
supports (dense / MoE / SSM / hybrid / enc-dec audio / VLM).  Each assigned
architecture gets a module in this package exporting ``CONFIG`` (the exact
published configuration, cited) and ``smoke_config()`` (a reduced variant for
CPU tests: <=2 layers, d_model <= 512, <= 4 experts).

Configs are pure data — no jax imports — so the launcher can enumerate them
before any device initialization (critical for the dry-run's XLA_FLAGS
ordering).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Iterable

__all__ = [
    "ModelConfig",
    "InputShape",
    "INPUT_SHAPES",
    "ARCH_IDS",
    "get_config",
    "get_smoke_config",
]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # --- identity -----------------------------------------------------------
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | audio | vlm
    source: str  # citation (arXiv id / model card)
    # --- trunk --------------------------------------------------------------
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 128
    d_ff: int = 0
    vocab_size: int = 0
    mlp_type: str = "swiglu"  # swiglu | gelu
    # --- attention ----------------------------------------------------------
    rope_theta: float = 10_000.0
    use_qk_norm: bool = False  # Qwen3
    sliding_window: int = 0  # 0 = full attention; >0 = window size
    # MLA (DeepSeek-V3): latent KV compression + decoupled RoPE dims.
    use_mla: bool = False
    mla_kv_rank: int = 512
    mla_q_rank: int = 1536
    mla_rope_dim: int = 64
    # --- normalization ------------------------------------------------------
    norm_type: str = "rmsnorm"  # rmsnorm | nonparametric_ln (OLMo)
    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim
    first_k_dense: int = 0  # DeepSeek-V3: first layers stay dense
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-3
    use_mtp: bool = False  # DeepSeek-V3 multi-token prediction head
    # --- SSM (Mamba2 / SSD) --------------------------------------------------
    ssm_state_dim: int = 0
    ssm_num_heads: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv_width: int = 4
    ssm_num_groups: int = 1
    # --- hybrid (Zamba2) ------------------------------------------------------
    attn_every: int = 0  # shared attention block every k trunk layers
    # --- encoder-decoder (Whisper) --------------------------------------------
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq_len: int = 1500  # Whisper: 30 s audio -> 1500 frames post-conv
    # --- modality frontend (stubbed per spec) ---------------------------------
    frontend: str = "none"  # none | audio | vision
    num_patches: int = 0  # VLM: visual tokens prepended to the text sequence
    # --- BranchyNet (the paper's technique) -----------------------------------
    branch_layers: tuple[int, ...] = ()  # 1-based trunk indices carrying exits
    branch_loss_weight: float = 0.3  # joint-training weight per branch
    exit_threshold: float = 0.5  # normalized-entropy exit threshold
    # --- serving --------------------------------------------------------------
    # Decode hot path: dispatch to the Pallas kernel suite (flash_decode
    # survivor-row attention, fused entropy-exit+argmax, ssd_update)?
    # None = auto: kernels on TPU, pure jnp elsewhere (an explicit True
    # off-TPU runs the kernels in interpret mode — tests/benchmarks).
    # Serving constructors (TierExecutor / engine / servers) can override.
    use_kernels: bool | None = None
    # --- numerics / training ---------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "float32"  # bfloat16 for the >100B configs (16 GB/chip)
    accum_dtype: str = "float32"  # grad-accumulation buffer dtype
    tie_embeddings: bool = False
    grad_accum: int = 1
    optimizer: str = "adamw"  # adamw | adafactor
    remat: bool = True
    # Shard the seq dim of remat-saved residual carries over "model"
    # (Megatron-style sequence parallelism for activation memory).
    seq_shard_activations: bool = False
    # --- sharding knobs (see repro/sharding/policy.py) -------------------------
    fsdp: bool = False  # additionally shard params over the data axes
    fsdp_axes: tuple[str, ...] = ("data",)
    # Expert parallelism: shard the expert axis over (data x model) jointly
    # (1 expert per chip at E == mesh size) instead of FSDP-gathering expert
    # weights — kills the dominant all-gathers of MoE training (§Perf).
    expert_parallel: bool = False
    # Decode-path experiment: constrain q/out to head-dim sharding so the
    # attention math runs in the KV cache's layout (kv-heads < model axis)
    # instead of XLA resharding q/cache every layer (§Perf pair 3).
    decode_qhd_shard: bool = False
    # Which expert-weight dim carries the FSDP shards: "d" gathers weights
    # per layer; "ff" keeps weights local and all-reduces the (smaller)
    # expert activations instead (§Perf pair 1, iteration 2).
    moe_fsdp_dim: str = "d"  # "d" | "ff"

    # ------------------------------------------------------------------ helpers
    @property
    def padded_vocab_size(self) -> int:
        """Embedding/unembedding table rows.  Vocabs that don't divide the
        16-way model axis (mamba2's 50280, whisper's 51865) are padded to a
        multiple of 256 — otherwise the (B, S, V) logits replicate across
        the model axis (observed: +100 GB/dev on the train_4k dry-runs).
        Pad logits are masked to -inf in every softmax/loss."""
        if self.vocab_size % 256 == 0 or self.vocab_size % 16 == 0:
            return self.vocab_size
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def attn_matmul_params(self) -> int:
        """Matmul parameters of one attention block (GQA or MLA) — the
        single source for num_params/active_params and the serving
        benchmarks' per-row decode FLOPs (2 FLOPs per MAC)."""
        d = self.d_model
        if self.arch_type not in ("dense", "moe", "vlm", "audio", "hybrid"):
            return 0
        if self.use_mla:
            return (
                d * self.mla_q_rank
                + self.mla_q_rank * self.num_heads * self.head_dim
                + d * (self.mla_kv_rank + self.mla_rope_dim)
                + self.mla_kv_rank * self.num_heads * (self.head_dim + self.head_dim)
                + self.num_heads * self.head_dim * d
            )
        return d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d

    def dense_mlp_matmul_params(self) -> int:
        """Matmul parameters of one dense MLP block."""
        return (3 if self.mlp_type == "swiglu" else 2) * self.d_model * self.d_ff

    def num_params(self) -> int:
        """Approximate parameter count (embeddings + trunk), for roofline's
        MODEL_FLOPS = 6*N*D and memory budgeting."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        attn = self.attn_matmul_params()
        if self.arch_type == "moe":
            shared = 3 * d * self.moe_d_ff * self.num_shared_experts
            routed = 3 * d * self.moe_d_ff * self.num_experts
            router = d * self.num_experts
            dense_mlp = 3 * d * ff if ff else 0
            n_moe = self.num_layers - self.first_k_dense
            per_layer_moe = attn + shared + routed + router
            per_layer_dense = attn + dense_mlp
            trunk = n_moe * per_layer_moe + self.first_k_dense * per_layer_dense
        elif self.arch_type == "ssm":
            inner = self.ssm_inner
            g = self.ssm_num_groups
            per_layer = (
                d * (2 * inner + 2 * g * self.ssm_state_dim + self.ssm_num_heads)
                + inner * d
            )
            trunk = self.num_layers * per_layer
        elif self.arch_type == "hybrid":
            inner = self.ssm_inner
            g = self.ssm_num_groups
            mamba = (
                d * (2 * inner + 2 * g * self.ssm_state_dim + self.ssm_num_heads)
                + inner * d
            )
            shared_attn = attn + 3 * d * ff  # one shared block, counted once
            trunk = self.num_layers * mamba + shared_attn
        else:
            mlp = self.dense_mlp_matmul_params()
            trunk = self.num_layers * (attn + mlp)
            if self.is_encoder_decoder:
                # encoder layers + decoder cross-attention
                trunk += self.num_encoder_layers * (attn + mlp) + self.num_layers * attn
        return emb + trunk

    def active_params(self) -> int:
        """Parameters touched per token (MoE: top-k + shared only)."""
        if self.arch_type != "moe":
            return self.num_params()
        d = self.d_model
        attn = self.attn_matmul_params()
        active_mlp = 3 * d * self.moe_d_ff * (
            self.experts_per_token + self.num_shared_experts
        )
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return emb + self.num_layers * (attn + active_mlp + d * self.num_experts)


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned workload shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS: tuple[str, ...] = (
    "phi3_mini_3_8b",
    "mamba2_130m",
    "zamba2_1_2b",
    "deepseek_v3_671b",
    "olmo_1b",
    "phi3_medium_14b",
    "qwen3_8b",
    "whisper_medium",
    "qwen3_moe_30b_a3b",
    "internvl2_76b",
)

_ALIAS = {
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "mamba2-130m": "mamba2_130m",
    "zamba2-1.2b": "zamba2_1_2b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "olmo-1b": "olmo_1b",
    "phi3-medium-14b": "phi3_medium_14b",
    "qwen3-8b": "qwen3_8b",
    "whisper-medium": "whisper_medium",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "internvl2-76b": "internvl2_76b",
    "b-alexnet": "b_alexnet",
}


def _module(arch: str):
    arch = _ALIAS.get(arch, arch).replace("-", "_")
    return importlib.import_module(f"repro.configs.{arch}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke_config()


def all_configs() -> Iterable[ModelConfig]:
    for a in ARCH_IDS:
        yield get_config(a)
