"""Mamba2-130M [arXiv:2405.21060] — attention-free SSM (SSD)."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    arch_type="ssm",
    source="arXiv:2405.21060",
    num_layers=24,
    d_model=768,
    d_ff=0,
    vocab_size=50280,
    ssm_state_dim=128,
    ssm_num_heads=24,  # expand*d / head_dim = 1536 / 64
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=64,
    tie_embeddings=True,
    branch_layers=(6, 12, 18),
    grad_accum=2,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=128,
        ssm_state_dim=16,
        ssm_num_heads=4,
        ssm_head_dim=64,
        ssm_chunk=16,
        vocab_size=512,
        branch_layers=(1,),
        remat=False,
    )
