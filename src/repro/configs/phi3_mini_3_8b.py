"""Phi-3-mini 3.8B [arXiv:2404.14219] — dense, RoPE + SwiGLU + GQA."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    arch_type="dense",
    source="arXiv:2404.14219",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    rope_theta=10_000.0,
    branch_layers=(8, 16, 24),
    grad_accum=8,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        branch_layers=(1,),
        remat=False,
    )
