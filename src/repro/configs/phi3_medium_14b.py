"""Phi-3-medium 14B [arXiv:2404.14219] — dense, GQA kv=10."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    arch_type="dense",
    source="arXiv:2404.14219",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=10,
    head_dim=128,
    d_ff=17920,
    vocab_size=100352,
    branch_layers=(10, 20, 30),
    fsdp=True,
    grad_accum=8,
    decode_qhd_shard=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=512,
        vocab_size=512,
        branch_layers=(1,),
        remat=False,
    )
