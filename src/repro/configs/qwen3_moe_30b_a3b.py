"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B] — 128 experts, top-8, GQA kv=4."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    arch_type="moe",
    source="hf:Qwen/Qwen3-30B-A3B",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=0,  # all layers MoE
    vocab_size=151936,
    use_qk_norm=True,
    rope_theta=1_000_000.0,
    num_experts=128,
    experts_per_token=8,
    moe_d_ff=768,
    branch_layers=(12, 24, 36),
    fsdp=True,
    grad_accum=8,
    decode_qhd_shard=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        vocab_size=512,
        num_experts=4,
        experts_per_token=2,
        moe_d_ff=128,
        branch_layers=(1,),
        fsdp=False,
        remat=False,
    )
