"""Whisper-medium [arXiv:2212.04356] — enc-dec; conv/mel frontend stubbed
(input_specs provides post-conv frame embeddings, per the spec carve-out).
Norms are RMSNorm in place of Whisper's LayerNorm (DESIGN.md adaptation)."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    arch_type="audio",
    source="arXiv:2212.04356",
    num_layers=24,  # decoder
    num_encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    mlp_type="gelu",
    vocab_size=51865,
    is_encoder_decoder=True,
    encoder_seq_len=1500,
    frontend="audio",
    branch_layers=(6, 12, 18),
    grad_accum=8,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        num_encoder_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        encoder_seq_len=32,
        branch_layers=(1,),
        remat=False,
    )
