"""OLMo-1B [arXiv:2402.00838] — dense with non-parametric LayerNorm."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    arch_type="dense",
    source="arXiv:2402.00838",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab_size=50304,
    norm_type="nonparametric_ln",
    tie_embeddings=True,
    branch_layers=(4, 8, 12),
    grad_accum=2,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        branch_layers=(1,),
        remat=False,
    )
