"""Sharding policy: param/activation/cache PartitionSpecs per architecture.

Axis conventions (DESIGN.md Sec. 5):

  * ``data`` (+ ``pod`` when present) — batch parallelism; also the FSDP
    axes for archs with ``cfg.fsdp`` (param shards are all-gathered per
    layer by XLA SPMD under the scan).
  * ``model`` — tensor parallelism: attention heads / FFN hidden / expert
    dim / vocab.

Every rule checks divisibility and falls back to replication — phi3-medium's
kv=10 heads or whisper's 51865 vocab must not crash the lowering.  KV caches
shard kv-heads over "model" when divisible, else head_dim (DESIGN.md).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

__all__ = ["ShardingPolicy", "make_policy"]


def _div(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    mesh: Mesh
    cfg: ModelConfig
    batch_axes: tuple[str, ...]  # ("pod","data") or ("data",)
    model_axis: str = "model"

    # ------------------------------------------------------------ helpers
    def _axis_size(self, name: str | tuple[str, ...]) -> int:
        if isinstance(name, tuple):
            return int(np.prod([self.mesh.shape[a] for a in name]))
        return self.mesh.shape[name]

    def _maybe(self, axis, dim: int):
        """axis if it divides dim else None (replicate)."""
        return axis if _div(dim, self._axis_size(axis)) else None

    def _fsdp_axes(self) -> tuple[str, ...] | None:
        if not self.cfg.fsdp:
            return None
        axes = tuple(a for a in self.cfg.fsdp_axes if a in self.mesh.shape)
        return axes or None

    # ------------------------------------------------------------ params
    def param_spec(self, path: str, shape: tuple[int, ...]) -> P:
        """Rule table keyed on the param's tree path (joined with '/').

        Stacked trunk params carry a leading layer axis (never sharded).
        """
        cfg = self.cfg
        tp = self.model_axis
        fsdp = self._fsdp_axes()
        stacked = self._is_stacked(path, shape)

        def spec(*dims):
            """dims for the *unstacked* suffix of the shape."""
            lead = (None,) * (len(shape) - len(dims))
            return P(*lead, *dims)

        leaf = path.split("/")[-1]

        # ---- embeddings / heads
        if leaf == "embed":
            return P(self._maybe(tp, shape[0]), fsdp and self._maybe(fsdp, shape[1]))
        if leaf == "lm_head":
            return P(fsdp and self._maybe(fsdp, shape[0]), self._maybe(tp, shape[1]))

        # ---- attention
        if re.search(r"(attn|xattn)/(wq|wk|wv|wq_b|wk_b|wv_b|wq_a|wkv_a)$", path):
            din, dout = shape[-2], shape[-1]
            return spec(
                fsdp and self._maybe(fsdp, din), self._maybe(tp, dout)
            )
        if re.search(r"(attn|xattn)/wo$", path):
            din, dout = shape[-2], shape[-1]
            return spec(self._maybe(tp, din), fsdp and self._maybe(fsdp, dout))

        # ---- dense MLP
        if re.search(r"mlp/(w_gate|w_up)$", path):
            return spec(fsdp and self._maybe(fsdp, shape[-2]), self._maybe(tp, shape[-1]))
        if re.search(r"mlp/w_down$", path):
            return spec(self._maybe(tp, shape[-2]), fsdp and self._maybe(fsdp, shape[-1]))

        # ---- MoE: expert axis on "model"; FSDP over the hidden dims.
        if re.search(r"moe/(w_gate|w_up|w_down)$", path):
            e = shape[-3]
            if cfg.expert_parallel:
                # Expert parallelism over the whole mesh: weights fully
                # local per expert group, no FSDP gathers (§Perf pair 1).
                # REFUTED at baseline dispatch: XLA SPMD reshards the token
                # activations instead of emitting all-to-alls (EXPERIMENTS
                # §Perf); kept for the shard_map dispatch follow-up.
                ep_axes = tuple(a for a in ("data", "model") if a in self.mesh.shape)
                if _div(e, self._axis_size(ep_axes)):
                    return spec(ep_axes, None, None)
            if cfg.moe_fsdp_dim == "ff" and fsdp:
                # FSDP over the expert-hidden dim: contraction partial-sums
                # all-reduce the activations instead of gathering weights.
                is_down = path.endswith("w_down")
                ff_idx = -2 if is_down else -1
                dims = [self._maybe(tp, e), None, None]
                dims[2 + ff_idx + 1] = self._maybe(fsdp, shape[ff_idx])
                return spec(*dims)
            return spec(
                self._maybe(tp, e),
                fsdp and self._maybe(fsdp, shape[-2]),
                None,
            )
        if re.search(r"moe/router$", path):
            return spec(fsdp and self._maybe(fsdp, shape[-2]), None)
        if re.search(r"moe/shared/(w_gate|w_up)$", path):
            return spec(fsdp and self._maybe(fsdp, shape[-2]), self._maybe(tp, shape[-1]))
        if re.search(r"moe/shared/w_down$", path):
            return spec(self._maybe(tp, shape[-2]), fsdp and self._maybe(fsdp, shape[-1]))

        # ---- Mamba2
        if re.search(r"mamba/(w_z|w_xbc)$", path):
            return spec(fsdp and self._maybe(fsdp, shape[-2]), self._maybe(tp, shape[-1]))
        if re.search(r"mamba/out_proj$", path):
            return spec(self._maybe(tp, shape[-2]), fsdp and self._maybe(fsdp, shape[-1]))
        if re.search(r"mamba/w_dt$", path):
            return spec(fsdp and self._maybe(fsdp, shape[-2]), None)
        if re.search(r"mamba/conv_w$", path):
            return spec(None, self._maybe(tp, shape[-1]))
        if re.search(r"mamba/(conv_b|norm_scale)$", path):
            return spec(self._maybe(tp, shape[-1]))

        # ---- everything else (norms, scalars): replicated.
        return P()

    @staticmethod
    def _is_stacked(path: str, shape) -> bool:
        return any(seg in path for seg in ("blocks/", "dense_blocks/"))

    def params_shardings(self, params_shapes) -> Any:
        """NamedShardings matching a pytree of ShapeDtypeStruct/arrays."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
        out = []
        for keypath, leaf in flat:
            path = "/".join(_key_str(k) for k in keypath)
            out.append(NamedSharding(self.mesh, self.param_spec(path, leaf.shape)))
        return jax.tree_util.tree_unflatten(treedef, out)

    # ------------------------------------------------------------ data
    def batch_spec_axes(self, batch_size: int):
        """Largest prefix of the batch axes that divides batch_size
        (long_500k has global_batch == 1: replicate)."""
        axes = []
        size = 1
        for a in self.batch_axes:
            if batch_size % (size * self.mesh.shape[a]) == 0:
                axes.append(a)
                size *= self.mesh.shape[a]
        return tuple(axes) or None

    def data_spec(self, shape: tuple[int, ...]) -> P:
        """Token-like inputs: batch over (pod, data) when divisible."""
        return P(self.batch_spec_axes(shape[0]), *(None,) * (len(shape) - 1))

    def data_shardings(self, tree) -> Any:
        return jax.tree_util.tree_map(
            lambda leaf: NamedSharding(self.mesh, self.data_spec(leaf.shape)),
            tree,
        )

    # ------------------------------------------------------------ caches
    def cache_spec(self, path: str, shape: tuple[int, ...]) -> P:
        """KV / SSM / latent caches.  Leading axis is the stacked layer axis
        for trunk caches; batch comes next."""
        cfg = self.cfg
        tp = self.model_axis
        leaf = path.split("/")[-1]
        if leaf in ("length", "pos"):
            return P(*(None,) * len(shape))

        def bsp(batch_dim_from_end: int):
            return self.batch_spec_axes(shape[-batch_dim_from_end])

        if leaf in ("k", "v") or "cross_kv" in path:
            # (L, B, C, K, D): kv-heads on model if divisible, else head_dim.
            kh, hd = shape[-2], shape[-1]
            if _div(kh, self._axis_size(tp)):
                return P(*(None,) * (len(shape) - 4), bsp(4), None, tp, None)
            return P(
                *(None,) * (len(shape) - 4), bsp(4), None, None,
                self._maybe(tp, hd),
            )
        if leaf in ("ckv", "k_rope"):
            # MLA latent: batch + latent dim (61L x 128B x 32k x 576 is NOT
            # tiny — 295 GB at decode_32k; model-shard the latent dim).
            return P(
                *(None,) * (len(shape) - 3), bsp(3), None,
                self._maybe(tp, shape[-1]),
            )
        if leaf == "ssm":
            # (L, B, H, P, N): heads on model if divisible else P dim.
            h, pdim = shape[-3], shape[-2]
            if _div(h, self._axis_size(tp)):
                return P(*(None,) * (len(shape) - 4), bsp(4), tp, None, None)
            return P(
                *(None,) * (len(shape) - 4), bsp(4), None,
                self._maybe(tp, pdim), None,
            )
        if leaf == "conv":
            return P(
                *(None,) * (len(shape) - 3), bsp(3), None,
                self._maybe(tp, shape[-1]),
            )
        return P(*(None,) * len(shape))

    def cache_shardings(self, cache_shapes) -> Any:
        flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
        out = []
        for keypath, leaf in flat:
            path = "/".join(_key_str(k) for k in keypath)
            out.append(NamedSharding(self.mesh, self.cache_spec(path, leaf.shape)))
        return jax.tree_util.tree_unflatten(treedef, out)

    # ------------------------------------------------------------ placement
    def shard_params(self, params) -> Any:
        """Place a concrete param pytree per :meth:`param_spec` (the serving
        entry point: ``TierExecutor`` calls this once at construction)."""
        return jax.device_put(params, self.params_shardings(params))

    def shard_caches(self, caches) -> Any:
        """Place a concrete cache pytree per :meth:`cache_spec`.  Serving
        callers run it on freshly initialized caches; sharded decode steps
        then keep the layouts through XLA's propagation."""
        return jax.device_put(caches, self.cache_shardings(caches))

    # ------------------------------------------------------------ optimizer
    def opt_state_shardings(self, params_shapes, optimizer_name: str) -> Any:
        """Shardings for the optimizer state pytree.

        AdamW's m/v mirror the params; Adafactor's factored vr/vc drop the
        last / second-to-last param axis from the spec.
        """
        if optimizer_name == "adamw":
            ps = self.params_shardings(params_shapes)
            return {"m": ps, "v": ps}
        if optimizer_name != "adafactor":
            raise ValueError(optimizer_name)

        flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
        out = []
        for keypath, leaf in flat:
            path = "/".join(_key_str(k) for k in keypath)
            spec = tuple(self.param_spec(path, leaf.shape)) + (None,) * (
                len(leaf.shape) - len(self.param_spec(path, leaf.shape))
            )
            spec = spec[: len(leaf.shape)]
            if len(leaf.shape) >= 2:
                out.append(
                    {
                        "vr": NamedSharding(self.mesh, P(*spec[:-1])),
                        "vc": NamedSharding(self.mesh, P(*spec[:-2], spec[-1])),
                    }
                )
            else:
                out.append({"v": NamedSharding(self.mesh, P(*spec))})
        return jax.tree_util.tree_unflatten(treedef, out)

    # ------------------------------------------------------------ misc
    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    def logits_spec(self) -> P:
        return P(self.batch_axes, None, self._maybe("model", self.cfg.vocab_size))


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def make_policy(mesh: Mesh, cfg: ModelConfig) -> ShardingPolicy:
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return ShardingPolicy(mesh=mesh, cfg=cfg, batch_axes=batch_axes)
