"""Activation-sharding context.

Model code is mesh-agnostic (CPU tests run without any mesh), but under
SPMD a few activations need explicit constraints — XLA's propagation
otherwise picks batch-replicated layouts for the unembedding matmuls
(observed: fp32 (16, 4096, vocab/16) logits with batch UNSHARDED, ~20 GB of
temp in the train_4k dry-runs).

The launcher activates :func:`activation_sharding` around trace time; the
model calls :func:`constrain` which is a no-op when no context is active.
Layout strings: one char per dim — 'b' batch (sharded over the batch axes
when divisible), 'v' model-shardable (vocab/heads), '.' unconstrained.
"""

from __future__ import annotations

import contextlib

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["activation_sharding", "constrain"]

_ACTIVE: tuple | None = None


@contextlib.contextmanager
def activation_sharding(mesh, batch_axes: tuple[str, ...], model_axis: str = "model"):
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = (mesh, tuple(batch_axes), model_axis)
    try:
        yield
    finally:
        _ACTIVE = prev


def constrain(x: jax.Array, layout: str) -> jax.Array:
    if _ACTIVE is None:
        return x
    mesh, batch_axes, model_axis = _ACTIVE
    assert len(layout) == x.ndim, (layout, x.shape)
    spec = []
    for ch, dim in zip(layout, x.shape):
        if ch == "b":
            axes, size = [], 1
            for a in batch_axes:
                if dim % (size * mesh.shape[a]) == 0:
                    axes.append(a)
                    size *= mesh.shape[a]
            spec.append(tuple(axes) if axes else None)
        elif ch == "v":
            spec.append(model_axis if dim % mesh.shape[model_axis] == 0 else None)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec))
    )
