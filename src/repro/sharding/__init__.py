"""repro.sharding — see module docstrings."""
