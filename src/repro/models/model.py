"""BranchyModel: backbone + side branches, with train / prefill / decode
entry points for every assigned architecture family.

Trunk layers are numbered 1..L like the paper's ``v_i``; side branches sit
after the layers in ``cfg.branch_layers`` and are evaluated by
``run_trunk(collect=...)`` which segments the layer scan at those points.
Branch heads are *tied* to the main LM head (per-branch norm + shared
unembedding) — early-exit LMs at 100k+ vocabs cannot afford a private
unembedding per exit; DESIGN.md records this adaptation.

Caches pytree (decode):
    {"blocks": stacked, "dense_blocks": stacked (MoE first-k),
     "shared_attn": stacked per-site (hybrid),
     "cross_kv": (L, B, S_enc, K, D) (whisper, set at encode time),
     "length": ()}

Caches are always *full-batch resident*: the survivor-compacted tier
runtime passes a ``rows`` index vector down ``run_trunk`` so a dense
sub-batch reads/writes only its rows in place — the C-sized KV buffers
never move at a tier hop.  KV slot validity (``pos``) is per sequence, so
a row that skipped a step downstream leaves a hole that later attention
masks (see models/attention.py).
"""

from __future__ import annotations

import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.calibration import normalized_entropy
from repro.kernels.ops import resolve_use_kernels
from repro.sharding.ctx import constrain
from repro.models.layers import (
    dense,
    embed,
    embedding_init,
    norm_apply,
    norm_init,
    sinusoidal_embed,
    sinusoidal_positions,
)
from repro.models.transformer import (
    BlockKind,
    block_apply,
    block_init,
    init_block_cache,
    run_stack,
    stack_init,
    stack_slice,
)

__all__ = [
    "branch_logits_stacked",
    "init_params",
    "init_caches",
    "run_trunk",
    "forward_train",
    "prefill",
    "decode_step",
    "embed_decode",
    "trunk_layout",
    "softmax_xent",
    "compute_dtype",
]

Params = dict


def compute_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------- layout
def trunk_layout(cfg: ModelConfig) -> list[tuple[str, BlockKind, int]]:
    """Ordered stacks composing the trunk: (param key, kind, n_layers)."""
    if cfg.arch_type in ("dense", "vlm"):
        return [("blocks", BlockKind("gqa", "dense"), cfg.num_layers)]
    if cfg.arch_type == "moe":
        mixer = "mla" if cfg.use_mla else "gqa"
        out = []
        if cfg.first_k_dense:
            out.append(("dense_blocks", BlockKind(mixer, "dense"), cfg.first_k_dense))
        out.append(("blocks", BlockKind(mixer, "moe"), cfg.num_layers - cfg.first_k_dense))
        return out
    if cfg.arch_type == "ssm":
        return [("blocks", BlockKind("mamba", "none"), cfg.num_layers)]
    if cfg.arch_type == "hybrid":
        return [("blocks", BlockKind("mamba", "none"), cfg.num_layers)]
    if cfg.arch_type == "audio":
        # decoder trunk only; the encoder is a separate stack in params.
        return [
            (
                "blocks",
                BlockKind("gqa", "dense", cross_attention=True, use_rope=False),
                cfg.num_layers,
            )
        ]
    raise ValueError(cfg.arch_type)


def hybrid_sites(cfg: ModelConfig) -> tuple[int, ...]:
    """Trunk layers after which the shared attention block runs (Zamba2)."""
    if cfg.arch_type != "hybrid" or not cfg.attn_every:
        return ()
    return tuple(
        i for i in range(cfg.attn_every, cfg.num_layers + 1, cfg.attn_every)
    )


_SHARED_ATTN_KIND = BlockKind("gqa", "dense")
_ENC_KIND = BlockKind("gqa", "dense", causal=False, use_rope=False)


# ---------------------------------------------------------------- init
def init_params(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 10)
    d = cfg.d_model
    p: Params = {"embed": embedding_init(ks[0], cfg.padded_vocab_size, d)}
    for i, (name, kind, n) in enumerate(trunk_layout(cfg)):
        p[name] = stack_init(ks[1 + i], cfg, n, kind)
    p["final_norm"] = norm_init(cfg.norm_type, d)
    if not cfg.tie_embeddings:
        p["lm_head"] = embedding_init(ks[4], cfg.padded_vocab_size, d).T
    if cfg.branch_layers:
        # Tied branch heads: per-branch norm only (see module docstring).
        p["branches"] = jax.vmap(lambda k: norm_init(cfg.norm_type, d))(
            jax.random.split(ks[5], len(cfg.branch_layers))
        ) if cfg.norm_type == "rmsnorm" else [
            norm_init(cfg.norm_type, d) for _ in cfg.branch_layers
        ]
    if cfg.arch_type == "hybrid":
        p["shared_attn"] = block_init(ks[6], cfg, _SHARED_ATTN_KIND)
    if cfg.arch_type == "audio":
        p["encoder"] = stack_init(ks[7], cfg, cfg.num_encoder_layers, _ENC_KIND)
        p["enc_norm"] = norm_init(cfg.norm_type, d)
    if cfg.use_mtp:
        p["mtp_block"] = block_init(ks[8], cfg, BlockKind(
            "mla" if cfg.use_mla else "gqa", "dense"))
        p["mtp_norm"] = norm_init(cfg.norm_type, d)
    if cfg.param_dtype == "bfloat16":
        # >100B configs: params live in bf16 (optimizer keeps factored fp32
        # statistics; see DESIGN.md Sec. 5 memory budget).
        p = jax.tree_util.tree_map(lambda a: a.astype(jnp.bfloat16), p)
    return p


def cache_capacity(cfg: ModelConfig, seq_len: int) -> int:
    """KV slots needed to decode against a context of ``seq_len``."""
    if cfg.sliding_window:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def init_caches(
    cfg: ModelConfig, batch: int, seq_len: int, dtype=None
) -> Params:
    dtype = dtype or compute_dtype(cfg)
    cap = cache_capacity(cfg, seq_len)
    caches: Params = {"length": jnp.zeros((), jnp.int32)}
    for name, kind, n in trunk_layout(cfg):
        one = init_block_cache(batch, cap, cfg, kind, dtype)
        caches[name] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (n, *a.shape)), one
        )
    if cfg.arch_type == "hybrid":
        sites = hybrid_sites(cfg)
        one = init_block_cache(batch, cap, cfg, _SHARED_ATTN_KIND, dtype)
        caches["shared_attn"] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (len(sites), *a.shape)), one
        )
    if cfg.arch_type == "audio":
        kh, hd = cfg.num_kv_heads, cfg.head_dim
        caches["cross_kv"] = (
            jnp.zeros((cfg.num_layers, batch, cfg.encoder_seq_len, kh, hd), dtype),
            jnp.zeros((cfg.num_layers, batch, cfg.encoder_seq_len, kh, hd), dtype),
        )
    return caches


# ---------------------------------------------------------------- trunk
def _segments(breaks: list[int], lo: int, hi: int) -> list[tuple[int, int]]:
    pts = sorted({lo, hi, *[b for b in breaks if lo < b < hi]})
    return list(zip(pts[:-1], pts[1:]))


def run_trunk(
    params: Params,
    h: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    caches: Params | None = None,
    *,
    layer_range: tuple[int, int] | None = None,  # absolute, 0-based [lo, hi)
    collect: tuple[int, ...] = (),  # 1-based "after layer i" collection points
    remat: bool = False,
    moe_dispatch: str = "einsum",
    rows: jax.Array | None = None,  # (Bsub,) cache rows: compacted decode
    #                                 survivors, or admission-prefill targets
    use_kernels: bool = False,  # decode: Pallas flash_decode / ssd_update
) -> tuple[jax.Array, Params | None, jax.Array, dict[int, jax.Array]]:
    """Run trunk layers [lo, hi), segmenting at collect points and (hybrid)
    shared-attention sites.  Returns (h, new_caches, aux, {layer: hidden}).

    ``rows``: h is a dense survivor sub-batch; every stateful block reads
    and writes only those rows of the full-batch caches (per-sequence slot
    validity in the KV caches masks the skipped rows' holes later).

    ``use_kernels`` (decode only): every stateful block's single-token
    math dispatches to the Pallas kernel suite — flash_decode streams
    ``rows`` out of the resident KV cache, ssd_update does the same for
    the SSM state."""
    layout = trunk_layout(cfg)
    total = sum(n for _, _, n in layout)
    lo, hi = layer_range or (0, total)
    sites = hybrid_sites(cfg)
    breaks = [*collect, *sites]
    # Stack boundaries are natural breaks too.
    acc = 0
    stack_bounds = {}
    for name, kind, n in layout:
        stack_bounds[name] = (acc, acc + n)
        acc += n
        breaks.append(acc)

    new_caches = dict(caches) if caches is not None else None
    cache_pieces: dict[str, list] = {name: [] for name, _, _ in layout}
    aux = jnp.zeros((), jnp.float32)
    collected: dict[int, jax.Array] = {}

    for seg_lo, seg_hi in _segments(breaks, lo, hi):
        # Locate the stack containing this segment (segments never straddle
        # stacks because stack bounds are break points).
        for name, kind, n in layout:
            s_lo, s_hi = stack_bounds[name]
            if s_lo <= seg_lo < s_hi:
                rel_lo, rel_hi = seg_lo - s_lo, seg_hi - s_lo
                sp = stack_slice(params[name], rel_lo, rel_hi)
                sc = (
                    stack_slice(caches[name], rel_lo, rel_hi)
                    if caches is not None
                    else None
                )
                cross = None
                if kind.cross_attention and caches is not None:
                    cross = jax.tree_util.tree_map(
                        lambda a: a[rel_lo:rel_hi], caches["cross_kv"]
                    )
                h, nc, a = run_stack(
                    sp, h, cfg, kind, positions, sc, cross,
                    remat=remat, moe_dispatch=moe_dispatch, rows=rows,
                    use_kernels=use_kernels,
                )
                h = constrain(h, "b..")
                aux = aux + a
                if nc is not None and caches is not None:
                    cache_pieces[name].append((rel_lo, rel_hi, nc))
                break
        else:
            raise AssertionError("segment outside all stacks")

        # Hybrid: the shared attention block runs with the layer it follows,
        # so a cut "after layer s" keeps site s on the edge side.
        if seg_hi in sites:
            site_idx = sites.index(seg_hi)
            site_cache = (
                jax.tree_util.tree_map(lambda a: a[site_idx], caches["shared_attn"])
                if caches is not None
                else None
            )
            h, nc, a = block_apply(
                params["shared_attn"], h, cfg, _SHARED_ATTN_KIND, positions,
                site_cache, rows=rows, use_kernels=use_kernels,
            )
            aux = aux + a
            if nc is not None and caches is not None:
                new_caches["shared_attn"] = jax.tree_util.tree_map(
                    lambda full, one: full.at[site_idx].set(one),
                    new_caches["shared_attn"], nc,
                )

        if seg_hi in collect:
            collected[seg_hi] = h

    if new_caches is not None:
        for name, pieces in cache_pieces.items():
            if not pieces:
                continue
            updated = new_caches[name]
            for rel_lo, rel_hi, nc in pieces:
                updated = jax.tree_util.tree_map(
                    lambda full, piece, lo_=rel_lo: jax.lax.dynamic_update_slice_in_dim(
                        full, piece.astype(full.dtype), lo_, axis=0
                    ),
                    updated, nc,
                )
            new_caches[name] = updated

    return h, new_caches, aux, collected


# ---------------------------------------------------------------- heads
def _unembed(params: Params, h: jax.Array, cfg: ModelConfig) -> jax.Array:
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = dense(w, h, h.dtype)
    if cfg.padded_vocab_size != cfg.vocab_size:
        # Vocab-padding rows never win a softmax (fused into the matmul).
        pad_mask = jnp.arange(cfg.padded_vocab_size) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, jnp.asarray(-1e30, logits.dtype))
    return logits


def _stacked_branch_norm(
    params: Params, hs: jax.Array, idx: Sequence[int], cfg: ModelConfig
) -> jax.Array:
    """Per-branch norm over stacked hiddens ``hs`` (K, ..., D); ``idx[k]``
    selects head k's row of the stacked ``params["branches"]`` tree.  Both
    norms reduce over the last axis only, so the stacked apply is bitwise
    the per-head apply."""
    if cfg.norm_type == "rmsnorm":
        scale = params["branches"]["scale"][np.asarray(idx)]  # (K, D)
        bcast = scale.reshape(scale.shape[0], *([1] * (hs.ndim - 2)), -1)
        return norm_apply(cfg.norm_type, {"scale": bcast}, hs)
    return norm_apply(cfg.norm_type, {}, hs)


def branch_logits_stacked(
    params: Params,
    collected: dict[int, jax.Array],
    cfg: ModelConfig,
    layers: Sequence[int] | None = None,
) -> tuple[tuple[int, ...], jax.Array | None]:
    """Batched tied exit heads: ONE stacked norm + ONE shared-unembedding
    einsum for every requested branch.

    The per-branch params are stored stacked ((K, D) scale tree for
    rmsnorm; parameter-free otherwise), so K heads price like one: the
    collected hiddens stack to (K, B, S, D), the norm applies over the
    stack, and the unembedding weight is read (and cast) once by a single
    (K*B*S, D) x (D, V) contraction instead of once per head.  Returns
    ``(layers, logits (K, B, S, V))`` in ``layers`` order — ``((), None)``
    when no requested layer was collected.  Per-head results are bitwise
    identical to the sequential per-branch path: the norm reductions and
    the contraction over D are row-independent."""
    want = cfg.branch_layers if layers is None else tuple(layers)
    present = tuple(l for l in want if l in collected)
    if not present:
        return (), None
    idx = [cfg.branch_layers.index(l) for l in present]
    hs = jnp.stack([collected[l] for l in present])  # (K, B, S, D)
    hn = _stacked_branch_norm(params, hs, idx, cfg)
    return present, _unembed(params, hn, cfg)


def _branch_logits(
    params: Params, collected: dict[int, jax.Array], cfg: ModelConfig
) -> dict[int, jax.Array]:
    """Tied early-exit heads: per-branch norm + shared unembedding,
    evaluated through the batched (K, B, S, V) path."""
    layers, stk = branch_logits_stacked(params, collected, cfg)
    return {layer: stk[k] for k, layer in enumerate(layers)}


def branch_logits_per_head(
    params: Params, collected: dict[int, jax.Array], cfg: ModelConfig
) -> dict[int, jax.Array]:
    """Sequential reference heads: one norm + one unembedding einsum PER
    branch (the pre-batching lowering).  The serving runtime keeps this as
    the parity baseline behind ``TierExecutor(batched_heads=False)`` —
    per-head outputs are bitwise identical to the stacked path."""
    out = {}
    for j, layer in enumerate(cfg.branch_layers):
        if layer not in collected:
            continue
        bn = jax.tree_util.tree_map(lambda a: a[j], params["branches"])
        hb = norm_apply(cfg.norm_type, bn, collected[layer])
        out[layer] = _unembed(params, hb, cfg)
    return out


def softmax_xent(
    logits: jax.Array, labels: jax.Array, mask: jax.Array | None = None
) -> jax.Array:
    """Mean masked token cross-entropy, fp32 reductions."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------- embedding
def _embed_inputs(
    params: Params, inputs: dict, cfg: ModelConfig
) -> tuple[jax.Array, jax.Array]:
    """Returns (h (B, S, d), positions (S,)).  Modality frontends are stubs
    per spec: precomputed patch/frame embeddings arrive in ``inputs``."""
    dtype = compute_dtype(cfg)
    if cfg.frontend == "vision":
        tok = embed(params["embed"], inputs["tokens"], dtype)
        h = jnp.concatenate([inputs["patch_embeds"].astype(dtype), tok], axis=1)
    else:
        h = embed(params["embed"], inputs["tokens"], dtype)
    h = constrain(h, "b..")
    s = h.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)
    if cfg.arch_type == "audio":
        # Whisper decoder uses absolute positions added to embeddings.
        h = h + sinusoidal_positions(s, cfg.d_model).astype(dtype)[None]
    return h, positions


def encode_audio(params: Params, frame_embeds: jax.Array, cfg: ModelConfig):
    """Whisper encoder over (stubbed) conv-frontend frame embeddings."""
    dtype = compute_dtype(cfg)
    h = frame_embeds.astype(dtype)
    h = h + sinusoidal_positions(h.shape[1], cfg.d_model).astype(dtype)[None]
    pos = jnp.arange(h.shape[1], dtype=jnp.int32)
    h, _, _ = run_stack(params["encoder"], h, cfg, _ENC_KIND, pos)
    return norm_apply(cfg.norm_type, params["enc_norm"], h)


def compute_cross_kv(params: Params, enc_out: jax.Array, cfg: ModelConfig):
    """Per-decoder-layer cross K/V, stacked (L, B, S_enc, K, D)."""
    b, s, _ = enc_out.shape
    kh, hd = cfg.num_kv_heads, cfg.head_dim

    def per_layer(xattn):
        k = dense(xattn["wk"], enc_out, enc_out.dtype).reshape(b, s, kh, hd)
        v = dense(xattn["wv"], enc_out, enc_out.dtype).reshape(b, s, kh, hd)
        return k, v

    return jax.vmap(per_layer)(params["blocks"]["xattn"])


# ---------------------------------------------------------------- train
def forward_train(
    params: Params,
    batch: dict,
    cfg: ModelConfig,
    *,
    moe_dispatch: str = "einsum",
) -> dict[str, jax.Array]:
    """Joint BranchyNet training loss (paper Sec. III / BranchyNet [5]):
    main CE + branch_loss_weight * sum_k CE_k (+ MoE aux, + MTP)."""
    h, positions = _embed_inputs(params, batch, cfg)
    caches = None
    if cfg.arch_type == "audio":
        enc_out = encode_audio(params, batch["frame_embeds"], cfg)
        cross = compute_cross_kv(params, enc_out, cfg)
        caches = None  # training path passes cross_kv through run_stack xs
        h2, _, aux, collected = _run_trunk_with_cross(
            params, h, cfg, positions, cross,
            collect=cfg.branch_layers, remat=cfg.remat,
        )
    else:
        h2, _, aux, collected = run_trunk(
            params, h, cfg, positions, caches,
            collect=cfg.branch_layers, remat=cfg.remat,
            moe_dispatch=moe_dispatch,
        )

    labels = batch["labels"]
    mask = batch.get("mask")
    n_patch = cfg.num_patches if cfg.frontend == "vision" else 0

    # Each head's loss is checkpointed: the (B, S, V) logits would otherwise
    # be SAVED for backward per head (fp32!) — with 3 branches + main + MTP
    # on a 128k vocab that alone was ~17 GB/device in the train_4k dry-run.
    @functools.partial(jax.checkpoint, prevent_cse=False)
    def head_loss(norm_params, h):
        hn = norm_apply(cfg.norm_type, norm_params, h)
        logits = constrain(_unembed(params, hn, cfg), "b.v")
        lt = logits[:, n_patch:] if n_patch else logits
        return softmax_xent(lt[:, :-1], labels[:, 1:],
                            None if mask is None else mask[:, 1:])

    main_loss = head_loss(params["final_norm"], h2)

    branch_losses = {}
    present = tuple(l for l in cfg.branch_layers if l in collected)
    if present:
        idx = [cfg.branch_layers.index(l) for l in present]

        # All K branch heads in one stacked norm + one unembedding einsum
        # (the serving runtime prices branches exactly this way, see
        # branch_logits_stacked).  Checkpointed like head_loss so no
        # (K, B, S, V) logits are saved for backward; the backward-pass
        # recompute does materialize all K heads' logits at once (vs one
        # head at a time sequentially) — the price of reading the
        # unembedding once instead of K times.
        @functools.partial(jax.checkpoint, prevent_cse=False)
        def branch_losses_fn(hs):
            hn = _stacked_branch_norm(params, hs, idx, cfg)
            logits = constrain(_unembed(params, hn, cfg), ".b.v")
            lt = logits[:, :, n_patch:] if n_patch else logits
            return jax.vmap(
                lambda lg: softmax_xent(
                    lg[:, :-1], labels[:, 1:],
                    None if mask is None else mask[:, 1:],
                )
            )(lt)

        bl = branch_losses_fn(jnp.stack([collected[l] for l in present]))
        for k, layer in enumerate(present):
            branch_losses[f"branch_{layer}"] = bl[k]

    loss = main_loss + cfg.branch_loss_weight * sum(branch_losses.values())
    loss = loss + cfg.router_aux_weight * aux

    if cfg.use_mtp:
        # DeepSeek-V3-style multi-token prediction: one extra block applied
        # to the trunk output predicts token t+2 (simplified single-depth
        # MTP).  Checkpointed for the same reason as head_loss.
        @functools.partial(jax.checkpoint, prevent_cse=False)
        def mtp_loss_fn(h):
            h_mtp, _, _ = block_apply(
                params["mtp_block"], h, cfg,
                BlockKind("mla" if cfg.use_mla else "gqa", "dense"), positions,
            )
            logits = constrain(_unembed(
                params, norm_apply(cfg.norm_type, params["mtp_norm"], h_mtp), cfg
            ), "b.v")
            lt = logits[:, n_patch:] if n_patch else logits
            return softmax_xent(lt[:, :-2], labels[:, 2:],
                                None if mask is None else mask[:, 2:])

        mtp_loss = mtp_loss_fn(h2)
        branch_losses["mtp"] = mtp_loss
        loss = loss + 0.3 * mtp_loss

    return {
        "loss": loss,
        "main_loss": main_loss,
        "aux_loss": aux,
        "branch_losses": branch_losses,
    }


def _run_trunk_with_cross(params, h, cfg, positions, cross_kv, *, collect, remat):
    """Training-mode trunk for enc-dec: cross_kv threaded through segments."""
    total = cfg.num_layers
    kind = trunk_layout(cfg)[0][1]
    aux = jnp.zeros((), jnp.float32)
    collected = {}
    lo = 0
    for stop in [*sorted(c for c in collect if 0 < c < total), total]:
        sp = stack_slice(params["blocks"], lo, stop)
        cr = jax.tree_util.tree_map(lambda a: a[lo:stop], cross_kv)
        h, _, a = run_stack(sp, h, cfg, kind, positions, None, cr, remat=remat)
        aux = aux + a
        if stop in collect:
            collected[stop] = h
        lo = stop
    return h, None, aux, collected


# ---------------------------------------------------------------- serving
def prefill(
    params: Params,
    inputs: dict,
    cfg: ModelConfig,
    caches: Params,
    *,
    moe_dispatch: str = "einsum",
    rows: jax.Array | None = None,
) -> tuple[jax.Array, Params]:
    """Process the full prompt; returns (last-position logits, caches).

    For attention caches, prefill runs the full-sequence path and then
    writes K/V into the cache tensors; SSM states come from the chunked
    scan's final state.  For the dry-run's prefill shape we lower exactly
    this function.

    ``rows`` (continuous-batching admission): ``inputs`` is a block of
    newly admitted prompts and ``caches`` the *resident full-batch* caches
    — prompt row ``i`` prefills into cache row ``rows[i]`` in place, ending
    exactly as a fresh solo prefill of that prompt (stale slots from the
    row's previous occupant reset to empty).  Other rows and the resident
    step counter are untouched; OOB sentinel rows drop their writes.
    """
    if rows is not None and cfg.arch_type == "audio":
        raise NotImplementedError(
            "row-targeted prefill does not cover encoder cross-KV caches"
        )
    h, positions = _embed_inputs(params, inputs, cfg)
    if cfg.arch_type == "audio":
        enc_out = encode_audio(params, inputs["frame_embeds"], cfg)
        caches = dict(caches)
        caches["cross_kv"] = compute_cross_kv(params, enc_out, cfg)
    h2, new_caches, _, _ = run_trunk(
        params, h, cfg, positions, caches, moe_dispatch=moe_dispatch,
        rows=rows,
    )
    if new_caches is not None and rows is None:
        new_caches["length"] = jnp.asarray(h.shape[1], jnp.int32)
    hF = norm_apply(cfg.norm_type, params["final_norm"], h2)
    logits = constrain(_unembed(params, hF[:, -1:], cfg), "b.v")
    return logits, new_caches


def embed_decode(
    params: Params, token: jax.Array, positions: jax.Array, cfg: ModelConfig
) -> jax.Array:
    """Embed one decode-step token (B, 1) — the entry point of whichever
    tier holds trunk layer 1 in a partitioned deployment.  ``positions``
    is the shared (1,) step position, or (B, 1) per-sequence positions
    under continuous batching."""
    dtype = compute_dtype(cfg)
    h = embed(params["embed"], token, dtype)
    if cfg.arch_type == "audio":
        # RoPE-free decoder: add the absolute sinusoidal embedding at `pos`.
        emb = sinusoidal_embed(positions, cfg.d_model).astype(dtype)
        h = h + (emb if positions.ndim == 2 else emb[None])
    return h


def decode_step(
    params: Params,
    token: jax.Array,  # (B, 1) int32
    pos: jax.Array,  # () int32 — absolute position of this token
    caches: Params,
    cfg: ModelConfig,
    *,
    moe_dispatch: str = "einsum",
    layer_range: tuple[int, int] | None = None,
    with_branches: bool = True,
    use_kernels: bool | None = None,  # None = cfg.use_kernels (auto on TPU)
) -> dict[str, Any]:
    """One decode step.  Returns logits, per-branch entropies/exit masks
    (the paper's confidence test at each side branch), and updated caches."""
    kernels = resolve_use_kernels(
        cfg.use_kernels if use_kernels is None else use_kernels
    )
    positions = pos[None].astype(jnp.int32)
    h = embed_decode(params, token, positions, cfg)

    collect = cfg.branch_layers if with_branches else ()
    h2, new_caches, _, collected = run_trunk(
        params, h, cfg, positions, caches,
        layer_range=layer_range, collect=collect, moe_dispatch=moe_dispatch,
        use_kernels=kernels,
    )
    out: dict[str, Any] = {}
    total = sum(n for _, _, n in trunk_layout(cfg))
    if layer_range is None or layer_range[1] == total:
        hF = norm_apply(cfg.norm_type, params["final_norm"], h2)
        out["logits"] = constrain(_unembed(params, hF, cfg), "b.v")[:, 0]
    else:
        out["hidden"] = h2  # partitioned execution: ship the residual stream

    if with_branches:
        bl = _branch_logits(params, collected, cfg)
        out["branch_logits"] = {k: v[:, 0] for k, v in bl.items()}
        out["branch_entropy"] = {
            k: normalized_entropy(v) for k, v in out["branch_logits"].items()
        }
        out["branch_exit"] = {
            k: e < cfg.exit_threshold for k, e in out["branch_entropy"].items()
        }
    if new_caches is not None:
        new_caches["length"] = caches["length"] + 1
    out["caches"] = new_caches
    return out
