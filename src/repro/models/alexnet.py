"""B-AlexNet — the paper's own evaluation network (Sec. VI).

AlexNet main branch + one side branch after the first conv/pool stage,
exactly as the paper's B-AlexNet [5].  Used by the paper-validation
benchmarks (Figs. 4-6): per-layer times and output sizes feed the
partitioner, and the branch posterior entropy drives calibration.

Layers are exposed individually (``layer_fns``) because the partitioner
needs per-layer costs — this is the paper's chain graph v_1..v_N.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["BAlexNetConfig", "init_b_alexnet", "layer_fns", "forward", "branch_forward"]

Params = dict


@dataclasses.dataclass(frozen=True)
class BAlexNetConfig:
    num_classes: int = 2  # the paper's cat-vs-dog task
    image_size: int = 224
    branch_after: int = 1  # side branch after the first conv stage (paper)


def _conv_init(key, kh, kw, cin, cout):
    scale = 1.0 / np.sqrt(kh * kw * cin)
    return {
        "w": scale * jax.random.normal(key, (kh, kw, cin, cout), jnp.float32),
        "b": jnp.zeros((cout,), jnp.float32),
    }


def _fc_init(key, din, dout):
    return {
        "w": (1.0 / np.sqrt(din)) * jax.random.normal(key, (din, dout), jnp.float32),
        "b": jnp.zeros((dout,), jnp.float32),
    }


def _conv(p, x, stride, padding="SAME"):
    y = jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + p["b"]


def _maxpool(x, k=3, s=2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, s, s, 1), "VALID"
    )


def init_b_alexnet(key, cfg: BAlexNetConfig = BAlexNetConfig()) -> Params:
    ks = jax.random.split(key, 10)
    return {
        "conv1": _conv_init(ks[0], 11, 11, 3, 64),
        "conv2": _conv_init(ks[1], 5, 5, 64, 192),
        "conv3": _conv_init(ks[2], 3, 3, 192, 384),
        "conv4": _conv_init(ks[3], 3, 3, 384, 256),
        "conv5": _conv_init(ks[4], 3, 3, 256, 256),
        "fc6": _fc_init(ks[5], 256 * 6 * 6, 4096),
        "fc7": _fc_init(ks[6], 4096, 4096),
        "fc8": _fc_init(ks[7], 4096, cfg.num_classes),
        # Side branch b_1: one conv + pooled classifier (BranchyNet [5]).
        "b1_conv": _conv_init(ks[8], 3, 3, 64, 32),
        "b1_fc": _fc_init(ks[9], 32 * 13 * 13, cfg.num_classes),
    }


def layer_fns(params: Params) -> list[tuple[str, Callable]]:
    """The main branch as the paper's chain v_1..v_N (conv stages fused with
    their pools, matching how the paper's Fig. 5 labels partition points)."""

    def l1(x):  # conv1 + pool1
        return _maxpool(jax.nn.relu(_conv(params["conv1"], x, 4)))

    def l2(x):  # conv2 + pool2
        return _maxpool(jax.nn.relu(_conv(params["conv2"], x, 1)))

    def l3(x):
        return jax.nn.relu(_conv(params["conv3"], x, 1))

    def l4(x):
        return jax.nn.relu(_conv(params["conv4"], x, 1))

    def l5(x):  # conv5 + pool5
        return _maxpool(jax.nn.relu(_conv(params["conv5"], x, 1)))

    def l6(x):
        flat = x.reshape(x.shape[0], -1)
        return jax.nn.relu(flat @ params["fc6"]["w"] + params["fc6"]["b"])

    def l7(x):
        return jax.nn.relu(x @ params["fc7"]["w"] + params["fc7"]["b"])

    def l8(x):
        return x @ params["fc8"]["w"] + params["fc8"]["b"]

    return [
        ("conv1", l1), ("conv2", l2), ("conv3", l3), ("conv4", l4),
        ("conv5", l5), ("fc6", l6), ("fc7", l7), ("fc8", l8),
    ]


def branch_forward(params: Params, h1: jax.Array) -> jax.Array:
    """Side branch b_1 logits from the conv1-stage output."""
    y = _maxpool(jax.nn.relu(_conv(params["b1_conv"], h1, 1)))
    return y.reshape(y.shape[0], -1) @ params["b1_fc"]["w"] + params["b1_fc"]["b"]


def forward(params: Params, images: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Returns (main logits, branch-1 logits)."""
    h = images
    fns = layer_fns(params)
    h1 = None
    for i, (_, fn) in enumerate(fns):
        h = fn(h)
        if i == 0:
            h1 = h
    return h, branch_forward(params, h1)
