"""Primitive layers: norms, RoPE, MLPs, embeddings.

Everything is functional: ``init_*`` builds a param pytree from a PRNG key,
``apply`` functions are pure.  Params are kept in fp32 and cast to the
compute dtype at use (standard mixed-precision discipline); norm reductions
stay in fp32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "dense_init",
    "dense",
    "rmsnorm_init",
    "rmsnorm",
    "nonparametric_ln",
    "norm_apply",
    "norm_init",
    "rope_frequencies",
    "apply_rope",
    "mlp_init",
    "mlp_apply",
    "embedding_init",
    "embed",
    "sinusoidal_positions",
]

Params = dict


def dense_init(key, d_in: int, d_out: int, scale: float | None = None) -> jax.Array:
    """Truncated-normal init, fan-in scaled (matches common LLM practice)."""
    if scale is None:
        scale = 1.0 / np.sqrt(d_in)
    return scale * jax.random.truncated_normal(
        key, -2.0, 2.0, (d_in, d_out), dtype=jnp.float32
    )


def dense(w: jax.Array, x: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return jnp.einsum("...i,io->...o", x.astype(dtype), w.astype(dtype))


# --------------------------------------------------------------------- norms
def rmsnorm_init(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(x.dtype)


def nonparametric_ln(x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """OLMo's non-parametric LayerNorm: no scale, no bias [arXiv:2402.00838]."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def norm_init(norm_type: str, d: int) -> Params:
    if norm_type == "rmsnorm":
        return rmsnorm_init(d)
    if norm_type == "nonparametric_ln":
        return {}  # parameter-free
    raise ValueError(norm_type)


def norm_apply(norm_type: str, params: Params, x: jax.Array) -> jax.Array:
    if norm_type == "rmsnorm":
        return rmsnorm(params, x)
    if norm_type == "nonparametric_ln":
        return nonparametric_ln(x)
    raise ValueError(norm_type)


# ---------------------------------------------------------------------- RoPE
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for rotary embedding; (head_dim // 2,) fp32."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponents)


def apply_rope(
    x: jax.Array,  # (..., seq, heads, head_dim)
    positions: jax.Array,  # (..., seq) absolute token positions
    theta: float,
) -> jax.Array:
    """Rotate pairs (x[2i], x[2i+1]); fp32 trig, output in input dtype."""
    head_dim = x.shape[-1]
    inv_freq = rope_frequencies(head_dim, theta)
    angles = positions[..., :, None].astype(jnp.float32) * inv_freq  # (..., S, D/2)
    cos = jnp.cos(angles)[..., :, None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int) -> jax.Array:
    """Whisper-style sinusoidal embedding table, (seq_len, d_model) fp32."""
    pos = np.arange(seq_len)[:, None]
    dim = np.arange(d_model // 2)[None, :]
    angle = pos / np.power(10_000.0, 2 * dim / d_model)
    table = np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)
    return jnp.asarray(table, jnp.float32)


def sinusoidal_embed(positions: jax.Array, d_model: int) -> jax.Array:
    """Sinusoidal embedding at dynamic (traced) positions; (..., d_model)."""
    dim = jnp.arange(d_model // 2, dtype=jnp.float32)
    angle = positions.astype(jnp.float32)[..., None] / jnp.power(
        10_000.0, 2 * dim / d_model
    )
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


# ----------------------------------------------------------------------- MLP
def mlp_init(key, d_model: int, d_ff: int, mlp_type: str) -> Params:
    ks = jax.random.split(key, 3)
    if mlp_type == "swiglu":
        return {
            "w_gate": dense_init(ks[0], d_model, d_ff),
            "w_up": dense_init(ks[1], d_model, d_ff),
            "w_down": dense_init(ks[2], d_ff, d_model),
        }
    if mlp_type == "gelu":
        return {
            "w_up": dense_init(ks[0], d_model, d_ff),
            "w_down": dense_init(ks[1], d_ff, d_model),
        }
    raise ValueError(mlp_type)


def mlp_apply(params: Params, x: jax.Array, mlp_type: str) -> jax.Array:
    dtype = x.dtype
    if mlp_type == "swiglu":
        g = dense(params["w_gate"], x, dtype)
        u = dense(params["w_up"], x, dtype)
        return dense(params["w_down"], jax.nn.silu(g) * u, dtype)
    if mlp_type == "gelu":
        u = dense(params["w_up"], x, dtype)
        return dense(params["w_down"], jax.nn.gelu(u), dtype)
    raise ValueError(mlp_type)


# ----------------------------------------------------------------- embedding
def embedding_init(key, vocab: int, d_model: int) -> jax.Array:
    return jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02


def embed(table: jax.Array, tokens: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return table.astype(dtype)[tokens]
