"""Attention: GQA (+qk_norm), MLA, sliding-window, KV caches.

Shapes follow the convention
    q: (B, Sq, K, G, D)   — K kv-head groups, G = num_heads // num_kv_heads
    k/v: (B, Sk, K, D)

Full-sequence softmax is computed *blockwise* (online softmax over KV
chunks, a jnp flash attention) so prefill_32k / train_4k never materialize
an (S, S) score tensor.  This function is also the reference oracle for the
Pallas flash_decode kernel (kernels/ref.py reuses it).

KV cache layout (dict):
    k, v: (B, C, K, D)    — C slots (max_len for full, window for ring)
    pos:  (B, C) int32    — absolute position stored in each slot, -1 empty
    length: () int32      — tokens decoded so far (write index = length % C)

``pos`` is per *sequence*: in the survivor-compacted tier runtime an
early-exited sequence skips the downstream tiers for that step, so its
slot stays -1 (a hole) while survivors' slots go valid — attention then
masks holes per row instead of attending stale/zero K/V.  Decode entry
points accept ``rows`` (a device-resident survivor index vector): the
sub-batch reads/writes only those rows of the full-batch cache, which is
what lets compaction happen without any host round trip.

MLA (DeepSeek-V3) caches the 512-d latent + decoupled-RoPE key instead:
    ckv: (B, C, kv_rank), k_rope: (B, C, rope_dim), pos, length
and uses the absorbed-matrix formulation at decode time.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import ops as kernel_ops
from repro.models.layers import apply_rope, dense, dense_init, rmsnorm, rmsnorm_init
from repro.sharding.ctx import constrain

__all__ = [
    "attn_init",
    "attn_apply",
    "mla_init",
    "mla_apply",
    "init_kv_cache",
    "init_mla_cache",
    "flash_attention",
    "NEG_INF",
]

NEG_INF = -1e30
Params = dict


# =============================================================== mask helpers
def _band_mask(q_pos: jax.Array, k_pos: jax.Array, window: int) -> jax.Array:
    """(..., Sq, Sk) bool: causal, optionally banded to `window`, k slot valid.

    ``k_pos`` may carry leading batch dims — (B, Sk) per-sequence slot
    validity — which broadcast against ``q_pos``'s (Sq,) to (B, Sq, Sk).
    """
    m = q_pos[..., :, None] >= k_pos[..., None, :]
    if window > 0:
        m &= q_pos[..., :, None] - k_pos[..., None, :] < window
    m &= k_pos[..., None, :] >= 0  # empty cache slots carry pos == -1
    return m


# ======================================================== flash attention (jnp)
def flash_attention(
    q: jax.Array,  # (B, Sq, K, G, D)
    k: jax.Array,  # (B, Sk, K, D)
    v: jax.Array,  # (B, Sk, K, D)
    q_pos: jax.Array,  # (Sq,)
    k_pos: jax.Array,  # (Sk,) shared, or (B, Sk) per-sequence slot validity
    *,
    window: int = 0,
    block_k: int = 1024,
    block_q: int = 1024,
    scale: float | None = None,
) -> jax.Array:
    """Online-softmax attention, scanning KV in chunks of ``block_k`` and
    (for long queries) scanning Q in chunks of ``block_q``.

    Memory is O(block_q * block_k) per score tile instead of O(Sq * Sk).
    fp32 accumulators.
    """
    b, sq, kh, g, d = q.shape
    if sq > block_q:
        # Outer sequential loop over query chunks (lax.map = memory-bound).
        pad_q = (-sq) % block_q
        qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
        qpos = jnp.pad(q_pos, (0, pad_q), constant_values=-(10**9))
        nq = qp.shape[1] // block_q
        qb = qp.reshape(b, nq, block_q, kh, g, d).transpose(1, 0, 2, 3, 4, 5)
        pb = qpos.reshape(nq, block_q)

        def one(args):
            qi, pi = args
            return flash_attention(
                qi, k, v, pi, k_pos,
                window=window, block_k=block_k, block_q=block_q, scale=scale,
            )

        out = jax.lax.map(one, (qb, pb))
        out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * block_q, kh, g, -1)
        return out[:, :sq]
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    return _flash_vjp(q, k, v, q_pos, k_pos, window, block_k, scale)


def _flash_blocks(k, v, k_pos, block_k):
    b = k.shape[0]
    sk = k.shape[1]
    pad = (-sk) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(
            k_pos,
            ((0, 0), (0, pad)) if k_pos.ndim == 2 else (0, pad),
            constant_values=-1,
        )
    nblk = k.shape[1] // block_k
    kb = k.reshape(b, nblk, block_k, k.shape[2], k.shape[3]).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nblk, block_k, v.shape[2], v.shape[3]).transpose(1, 0, 2, 3, 4)
    if k_pos.ndim == 2:  # per-sequence slot validity: (B, Sk) -> (nblk, B, bk)
        pb = k_pos.reshape(b, nblk, block_k).transpose(1, 0, 2)
    else:
        pb = k_pos.reshape(nblk, block_k)
    return kb, vb, pb, pad


def _expand_mask(mask: jax.Array) -> jax.Array:
    """Broadcast a band mask to score rank (B, Sq, K, G, bk): the mask is
    (Sq, bk) for shared slot positions, (B, Sq, bk) for per-sequence ones."""
    if mask.ndim == 2:
        return mask[None, :, None, None, :]
    return mask[:, :, None, None, :]


def _flash_fwd_core(q, k, v, q_pos, k_pos, window, block_k, scale):
    """Returns (out, m, l) — softmax stats kept for the recompute backward."""
    b, sq, kh, g, d = q.shape
    dv = v.shape[-1]  # may differ from d (MLA: RoPE-extended keys)
    kb, vb, pb, _ = _flash_blocks(k, v, k_pos, block_k)
    qf = (q * scale).astype(jnp.float32)

    def step(carry, blk):
        m, l, acc = carry
        kblk, vblk, posb = blk
        s = jnp.einsum("bqkgd,bskd->bqkgs", qf, kblk.astype(jnp.float32))
        mask = _band_mask(q_pos, posb, window)  # (Sq, bk) or (B, Sq, bk)
        s = jnp.where(_expand_mask(mask), s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqkgs,bskd->bqkgd", p, vblk.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, sq, kh, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, kh, g), jnp.float32)
    a0 = jnp.zeros((b, sq, kh, g, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype), m, l


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def _flash_vjp(q, k, v, q_pos, k_pos, window, block_k, scale):
    return _flash_fwd_core(q, k, v, q_pos, k_pos, window, block_k, scale)[0]


def _flash_vjp_fwd(q, k, v, q_pos, k_pos, window, block_k, scale):
    out, m, l = _flash_fwd_core(q, k, v, q_pos, k_pos, window, block_k, scale)
    return out, (q, k, v, q_pos, k_pos, out, m, l)


def _flash_vjp_bwd(window, block_k, scale, res, dout):
    """Flash backward: recompute p blockwise; nothing O(Sq x Sk) is ever
    materialized and — crucially — nothing per-block is *saved* (the naive
    autodiff of the forward scan keeps every block's p matrix alive, which
    is what blew the train_4k dry-run memory; EXPERIMENTS §Perf)."""
    q, k, v, q_pos, k_pos, out, m, l = res
    b, sq, kh, g, d = q.shape
    dv = v.shape[-1]
    kb, vb, pb, pad = _flash_blocks(k, v, k_pos, block_k)

    qf = (q * scale).astype(jnp.float32)
    do = dout.astype(jnp.float32)
    lsafe = jnp.maximum(l, 1e-30)
    # delta = rowwise sum(dout * out) (the softmax Jacobian diagonal term).
    delta = jnp.sum(do * out.astype(jnp.float32), axis=-1)  # (B,Sq,K,G)

    def step(dq, blk):
        kblk, vblk, posb = blk
        s = jnp.einsum("bqkgd,bskd->bqkgs", qf, kblk.astype(jnp.float32))
        mask = _band_mask(q_pos, posb, window)
        s = jnp.where(_expand_mask(mask), s, NEG_INF)
        p = jnp.exp(s - m[..., None]) / lsafe[..., None]  # (B,Sq,K,G,bk)
        dvb = jnp.einsum("bqkgs,bqkgd->bskd", p, do)  # (B,bk,K,Dv)
        dp = jnp.einsum("bqkgd,bskd->bqkgs", do, vblk.astype(jnp.float32))
        ds = p * (dp - delta[..., None])  # (B,Sq,K,G,bk)
        dq = dq + jnp.einsum("bqkgs,bskd->bqkgd", ds, kblk.astype(jnp.float32))
        dkb = jnp.einsum("bqkgs,bqkgd->bskd", ds, qf)
        return dq, (dkb, dvb)

    dq0 = jnp.zeros((b, sq, kh, g, d), jnp.float32)
    dq, (dkb, dvb) = jax.lax.scan(step, dq0, (kb, vb, pb))
    # (nblk, B, bk, K, D) -> (B, Sk(+pad), K, D), drop padding.
    sk_p = dkb.shape[0] * dkb.shape[2]
    dk = dkb.transpose(1, 0, 2, 3, 4).reshape(b, sk_p, kh, d)
    dvf = dvb.transpose(1, 0, 2, 3, 4).reshape(b, sk_p, kh, dv)
    if pad:
        dk = dk[:, :-pad]
        dvf = dvf[:, :-pad]
    # s = scale * q.k: dk used qf (scale already folded in); dq needs it.
    dq = dq * scale
    return (
        dq.astype(q.dtype),
        dk.astype(k.dtype),
        dvf.astype(v.dtype),
        None,  # q_pos (int)
        None,  # k_pos (int)
    )


_flash_vjp.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


# =================================================================== KV cache
def init_kv_cache(
    batch: int, capacity: int, num_kv_heads: int, head_dim: int, dtype=jnp.bfloat16
) -> Params:
    return {
        "k": jnp.zeros((batch, capacity, num_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, capacity, num_kv_heads, head_dim), dtype),
        "pos": jnp.full((batch, capacity), -1, jnp.int32),
        "length": jnp.zeros((), jnp.int32),
    }


def _cache_write(
    cache: Params,
    k_new: jax.Array,
    v_new: jax.Array,
    rows: jax.Array | None = None,
    positions: jax.Array | None = None,
) -> Params:
    """Write one decode step (Sq == 1) into the (ring) cache.

    ``rows=None`` writes every batch row (the masked full-batch path).
    ``rows`` (Bsub,) writes only those rows of the full-batch cache — the
    survivor-compacted path — leaving excluded rows' slots untouched (their
    per-sequence ``pos`` stays -1, so attention masks the hole).

    ``positions`` with a batch dim ((B|Bsub, 1), the continuous-batching
    runtime) makes the write *per sequence*: row i writes its own ring slot
    ``positions[i] % C`` and records its own absolute position — requests
    admitted at different times coexist in one cache.  A 1-D ``positions``
    (or None) keeps the historical lock-step write at ``length % C``.
    """
    c = cache["k"].shape[1]
    if positions is not None and positions.ndim == 2:
        pos_vec = positions[:, 0].astype(jnp.int32)
        idx = pos_vec % c
        br = (
            rows
            if rows is not None
            else jnp.arange(k_new.shape[0], dtype=jnp.int32)
        )
        k = cache["k"].at[br, idx].set(k_new[:, 0], mode="drop")
        v = cache["v"].at[br, idx].set(v_new[:, 0], mode="drop")
        pos = cache["pos"].at[br, idx].set(pos_vec, mode="drop")
        return {"k": k, "v": v, "pos": pos, "length": cache["length"] + 1}
    idx = cache["length"] % c
    if rows is None:
        k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, idx, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, idx, axis=1)
        b = cache["pos"].shape[0]
        pos = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], jnp.broadcast_to(cache["length"], (b, 1)), idx, axis=1
        )
    else:
        # mode="drop": a padding row that already exited carries an
        # out-of-bounds sentinel — its write is skipped, leaving a hole.
        k = cache["k"].at[rows, idx].set(k_new[:, 0], mode="drop")
        v = cache["v"].at[rows, idx].set(v_new[:, 0], mode="drop")
        pos = cache["pos"].at[rows, idx].set(cache["length"], mode="drop")
    return {"k": k, "v": v, "pos": pos, "length": cache["length"] + 1}


def _cache_prefill(cache: Params, k: jax.Array, v: jax.Array) -> Params:
    """Write a whole prompt (S tokens at positions 0..S-1) into the cache,
    honoring the ring invariant slot = position % capacity so subsequent
    decode steps continue seamlessly."""
    s = k.shape[1]
    b = k.shape[0]
    cap = cache["k"].shape[1]
    if s >= cap:
        tail_k, tail_v = k[:, s - cap :], v[:, s - cap :]
        tail_pos = jnp.broadcast_to(
            jnp.arange(s - cap, s, dtype=jnp.int32), (b, cap)
        )
        shift = s % cap
        new_k = jnp.roll(tail_k, shift, axis=1)
        new_v = jnp.roll(tail_v, shift, axis=1)
        new_pos = jnp.roll(tail_pos, shift, axis=1)
    else:
        new_k = jnp.concatenate([k, cache["k"][:, s:]], axis=1)
        new_v = jnp.concatenate([v, cache["v"][:, s:]], axis=1)
        new_pos = jnp.concatenate(
            [jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s)),
             cache["pos"][:, s:]],
            axis=1,
        )
    return {
        "k": new_k.astype(cache["k"].dtype),
        "v": new_v.astype(cache["v"].dtype),
        "pos": new_pos,
        "length": jnp.asarray(s, jnp.int32),
    }


def _cache_prefill_rows(
    cache: Params, k: jax.Array, v: jax.Array, rows: jax.Array
) -> Params:
    """Row-targeted prompt prefill: write rows ``rows`` of the resident
    full-batch cache as if each were a *freshly initialized* cache that
    just prefilled this prompt — slots past the prompt reset to empty
    (pos = -1), so no stale K/V from the row's previous occupant can ever
    look valid.  Other rows (and the resident step counter) are untouched;
    OOB sentinel rows (admission-group padding) drop their writes."""
    fresh = _cache_prefill(
        init_kv_cache(k.shape[0], cache["k"].shape[1], k.shape[2], k.shape[3],
                      cache["k"].dtype),
        k, v,
    )
    return {
        "k": cache["k"].at[rows].set(fresh["k"], mode="drop"),
        "v": cache["v"].at[rows].set(fresh["v"], mode="drop"),
        "pos": cache["pos"].at[rows].set(fresh["pos"], mode="drop"),
        "length": cache["length"],
    }


# ============================================================== standard GQA
def attn_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p = {
        "wq": dense_init(ks[0], d, cfg.q_dim),
        "wk": dense_init(ks[1], d, cfg.kv_dim),
        "wv": dense_init(ks[2], d, cfg.kv_dim),
        "wo": dense_init(ks[3], cfg.q_dim, d),
    }
    if cfg.use_qk_norm:
        p["q_norm"] = rmsnorm_init(cfg.head_dim)
        p["k_norm"] = rmsnorm_init(cfg.head_dim)
    return p


def attn_apply(
    params: Params,
    x: jax.Array,  # (B, S, d_model)
    cfg: ModelConfig,
    positions: jax.Array,  # (S,) absolute positions of x's tokens
    cache: Params | None = None,
    *,
    use_rope: bool = True,
    window: int | None = None,
    kv_override: tuple[jax.Array, jax.Array] | None = None,  # cross-attention
    rows: jax.Array | None = None,  # (Bsub,) survivor rows of the full cache
    use_kernels: bool = False,  # decode: dispatch to the Pallas flash_decode
) -> tuple[jax.Array, Params | None]:
    """One attention op.  cache=None -> full (training/prefill) attention;
    cache given -> single-step decode against the cache.  ``kv_override``
    supplies precomputed encoder K/V for cross-attention (no cache write).

    ``rows``: x is a compacted survivor sub-batch (decode) or a block of
    newly admitted prompts (prefill, s > 1); row ``i`` of x reads/writes
    row ``rows[i]`` of the full-batch cache.

    ``positions`` may be per sequence at decode time — (B, 1) instead of
    the shared (1,) — so requests admitted at different steps decode at
    their own absolute positions (continuous batching): RoPE, the banded
    mask and the ring-slot write all follow the row's own position.

    ``use_kernels`` (decode only): the single-token attention runs in the
    Pallas flash_decode kernel, which streams the survivor rows straight
    out of the full-batch resident cache through a scalar-prefetched row
    map (zero gather copies) instead of the jnp ``cache[...][rows]``
    gather + flash_attention.  GQA head grouping, per-sequence ``pos``
    slot validity and sliding windows all ride through; prefill/train
    paths ignore the flag."""
    b, s, _ = x.shape
    kh, hd = cfg.num_kv_heads, cfg.head_dim
    g = cfg.num_heads // kh
    window = cfg.sliding_window if window is None else window
    dtype = x.dtype

    q = dense(params["wq"], x, dtype).reshape(b, s, kh * g, hd)
    if kv_override is None:
        k = dense(params["wk"], x, dtype).reshape(b, s, kh, hd)
        v = dense(params["wv"], x, dtype).reshape(b, s, kh, hd)
    else:
        k, v = kv_override

    if cfg.use_qk_norm:
        q = rmsnorm(params["q_norm"], q)
        if kv_override is None:
            k = rmsnorm(params["k_norm"], k)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        if kv_override is None:
            k = apply_rope(k, positions, cfg.rope_theta)

    qg = q.reshape(b, s, kh, g, hd)

    if cache is not None and s > 1:
        # -------- prefill with cache write-through: full-sequence attention
        # plus populating the (ring) cache for subsequent decode steps.
        # ``rows`` targets the write at those rows of the resident
        # full-batch cache (continuous-batching admission); the prompt's
        # attention itself never reads the cache, so it is identical to a
        # fresh solo prefill by construction.
        new_cache = (
            _cache_prefill(cache, k, v) if rows is None
            else _cache_prefill_rows(cache, k, v, rows)
        )
        out = flash_attention(
            qg, k, v, positions, positions, window=window, block_k=min(1024, s)
        )
    elif cache is not None:
        # -------- decode: write this step, attend over the whole cache.
        cache = _cache_write(cache, k, v, rows, positions)
        if cfg.decode_qhd_shard:
            # Run attention in the cache's head-dim-sharded layout: scores
            # become partial sums (all-reduce) instead of resharding the
            # cache or q every layer (§Perf).
            qg = constrain(qg, "b...v")
        if use_kernels:
            # Pallas flash_decode: the survivor row map is a scalar-prefetch
            # operand, so the kernel DMAs only rows ``rows`` of the resident
            # cache — the compacted sub-batch attends in place, no gather.
            # Per-sequence query positions ((B, 1), continuous batching)
            # ride the same scalar-prefetch path as a (B,) vector.
            q_pos = positions[:, 0] if positions.ndim == 2 else positions[0]
            out = kernel_ops.flash_decode(
                qg.reshape(b, kh * g, hd),
                cache["k"], cache["v"], cache["pos"], q_pos,
                rows, window=window,
            ).reshape(b, 1, kh, g, hd)
        else:
            if rows is None:
                ck, cv, cp = cache["k"], cache["v"], cache["pos"]
            else:
                # jnp compacted path: gather the survivor rows and hope XLA
                # fuses the gather into the attention (the kernel path
                # above is the copy-free version of this).
                ck, cv, cp = (
                    cache["k"][rows], cache["v"][rows], cache["pos"][rows]
                )
            out = flash_attention(
                qg, ck, cv, positions, cp,
                window=window, block_k=min(1024, ck.shape[1]),
            )
        new_cache = cache
    elif kv_override is not None:
        # -------- cross-attention: bidirectional over encoder frames.
        enc_pos = jnp.arange(k.shape[1], dtype=jnp.int32)
        out = flash_attention(
            qg, k, v, jnp.full_like(positions, k.shape[1]), enc_pos,
            window=0, block_k=min(1024, k.shape[1]),
        )
        new_cache = None
    else:
        # -------- training / prefill: causal (optionally banded).
        out = flash_attention(
            qg, k, v, positions, positions, window=window,
            block_k=min(1024, s),
        )
        new_cache = None

    out = out.reshape(b, s, kh * g * hd)
    return dense(params["wo"], out, dtype), new_cache


# ==================================================================== MLA
def mla_init(key, cfg: ModelConfig) -> Params:
    """DeepSeek-V3 Multi-head Latent Attention [arXiv:2412.19437].

    Queries go through a low-rank bottleneck (q_rank); keys/values through a
    shared latent (kv_rank) plus a small decoupled-RoPE subspace shared by
    all heads.  Only (latent, k_rope) is cached.
    """
    ks = jax.random.split(key, 8)
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    r_q, r_kv, r_rope = cfg.mla_q_rank, cfg.mla_kv_rank, cfg.mla_rope_dim
    return {
        "wq_a": dense_init(ks[0], d, r_q),
        "q_norm": rmsnorm_init(r_q),
        "wq_b": dense_init(ks[1], r_q, h * (hd + r_rope)),
        "wkv_a": dense_init(ks[2], d, r_kv + r_rope),
        "kv_norm": rmsnorm_init(r_kv),
        "wk_b": dense_init(ks[3], r_kv, h * hd),  # latent -> per-head key
        "wv_b": dense_init(ks[4], r_kv, h * hd),  # latent -> per-head value
        "wo": dense_init(ks[5], h * hd, d),
    }


def init_mla_cache(batch: int, capacity: int, cfg: ModelConfig, dtype=jnp.bfloat16):
    return {
        "ckv": jnp.zeros((batch, capacity, cfg.mla_kv_rank), dtype),
        "k_rope": jnp.zeros((batch, capacity, cfg.mla_rope_dim), dtype),
        "pos": jnp.full((batch, capacity), -1, jnp.int32),
        "length": jnp.zeros((), jnp.int32),
    }


def _mla_prefill_cache(
    cache: Params, ckv: jax.Array, k_rope: jax.Array
) -> Params:
    """Prefill write-through of the MLA latent cache (ring invariant)."""
    b, s, _ = ckv.shape
    cap = cache["ckv"].shape[1]
    if s >= cap:
        shift = s % cap
        return {
            "ckv": jnp.roll(ckv[:, s - cap :], shift, axis=1).astype(
                cache["ckv"].dtype
            ),
            "k_rope": jnp.roll(k_rope[:, s - cap :], shift, axis=1).astype(
                cache["k_rope"].dtype
            ),
            "pos": jnp.roll(
                jnp.broadcast_to(
                    jnp.arange(s - cap, s, dtype=jnp.int32), (b, cap)
                ),
                shift,
                axis=1,
            ),
            "length": jnp.asarray(s, jnp.int32),
        }
    return {
        "ckv": jnp.concatenate([ckv, cache["ckv"][:, s:]], 1).astype(
            cache["ckv"].dtype
        ),
        "k_rope": jnp.concatenate([k_rope, cache["k_rope"][:, s:]], 1).astype(
            cache["k_rope"].dtype
        ),
        "pos": jnp.concatenate(
            [jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s)),
             cache["pos"][:, s:]],
            1,
        ),
        "length": jnp.asarray(s, jnp.int32),
    }


def _mla_prefill_rows(
    cache: Params, ckv: jax.Array, k_rope: jax.Array, rows: jax.Array, cfg
) -> Params:
    """Row-targeted MLA prompt prefill (see :func:`_cache_prefill_rows`):
    each target row ends exactly as a fresh solo prefill — tail slots reset
    to empty — and the resident step counter is untouched."""
    fresh = _mla_prefill_cache(
        init_mla_cache(ckv.shape[0], cache["ckv"].shape[1], cfg,
                       cache["ckv"].dtype),
        ckv, k_rope,
    )
    return {
        "ckv": cache["ckv"].at[rows].set(fresh["ckv"], mode="drop"),
        "k_rope": cache["k_rope"].at[rows].set(fresh["k_rope"], mode="drop"),
        "pos": cache["pos"].at[rows].set(fresh["pos"], mode="drop"),
        "length": cache["length"],
    }


def _mla_qkr(params, x, cfg, positions):
    """Shared query path: returns (q_nope, q_rope) with RoPE applied."""
    b, s, _ = x.shape
    h, hd, r_rope = cfg.num_heads, cfg.head_dim, cfg.mla_rope_dim
    dtype = x.dtype
    qa = rmsnorm(params["q_norm"], dense(params["wq_a"], x, dtype))
    qb = dense(params["wq_b"], qa, dtype).reshape(b, s, h, hd + r_rope)
    q_nope, q_rope = qb[..., :hd], qb[..., hd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_apply(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    cache: Params | None = None,
    rows: jax.Array | None = None,  # (Bsub,) cache rows: decode survivors,
    #                                 or admission targets at prefill (s > 1)
) -> tuple[jax.Array, Params | None]:
    b, s, d = x.shape
    h, hd, r_rope = cfg.num_heads, cfg.head_dim, cfg.mla_rope_dim
    r_kv = cfg.mla_kv_rank
    dtype = x.dtype
    scale = 1.0 / np.sqrt(hd + r_rope)

    q_nope, q_rope = _mla_qkr(params, x, cfg, positions)

    kv = dense(params["wkv_a"], x, dtype)  # (B, S, r_kv + r_rope)
    ckv = rmsnorm(params["kv_norm"], kv[..., :r_kv])
    k_rope = apply_rope(kv[..., None, r_kv:], positions, cfg.rope_theta)[:, :, 0]

    if cache is None or s > 1:
        # Naive (train/prefill) form: expand latent to per-head K/V.
        k_nope = dense(params["wk_b"], ckv, dtype).reshape(b, s, h, hd)
        v = dense(params["wv_b"], ckv, dtype).reshape(b, s, h, hd)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, r_rope))],
            axis=-1,
        )
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = flash_attention(
            q_full.reshape(b, s, h, 1, hd + r_rope),
            k_full,
            v,
            positions,
            positions,
            window=cfg.sliding_window,
            block_k=min(1024, s),
            scale=scale,
        ).reshape(b, s, h, hd)
        new_cache = None
        if cache is not None:
            # Prefill write-through of the latent cache (ring invariant);
            # ``rows`` targets admitted rows of the resident cache.
            new_cache = (
                _mla_prefill_cache(cache, ckv, k_rope) if rows is None
                else _mla_prefill_rows(cache, ckv, k_rope, rows, cfg)
            )
    else:
        # Absorbed decode: score and read directly in the latent space.
        assert s == 1
        c = cache["ckv"].shape[1]
        per_seq = positions.ndim == 2  # continuous batching: (B|Bsub, 1)
        if per_seq:
            pos_vec = positions[:, 0].astype(jnp.int32)
            idx = pos_vec % c
            pos_val = pos_vec
        else:
            idx = cache["length"] % c
            pos_val = cache["length"]
        if rows is None and not per_seq:
            cache = {
                "ckv": jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv, idx, 1),
                "k_rope": jax.lax.dynamic_update_slice_in_dim(
                    cache["k_rope"], k_rope, idx, 1
                ),
                "pos": jax.lax.dynamic_update_slice_in_dim(
                    cache["pos"],
                    jnp.broadcast_to(cache["length"], (cache["pos"].shape[0], 1)),
                    idx,
                    axis=1,
                ),
                "length": cache["length"] + 1,
            }
            ckv_r, rope_r, pos_r = cache["ckv"], cache["k_rope"], cache["pos"]
        else:
            br = rows if rows is not None else jnp.arange(b, dtype=jnp.int32)
            cache = {
                "ckv": cache["ckv"].at[br, idx].set(ckv[:, 0], mode="drop"),
                "k_rope": cache["k_rope"].at[br, idx].set(
                    k_rope[:, 0], mode="drop"
                ),
                "pos": cache["pos"].at[br, idx].set(pos_val, mode="drop"),
                "length": cache["length"] + 1,
            }
            if rows is None:
                ckv_r, rope_r, pos_r = (
                    cache["ckv"], cache["k_rope"], cache["pos"]
                )
            else:
                ckv_r = cache["ckv"][rows]
                rope_r = cache["k_rope"][rows]
                pos_r = cache["pos"][rows]
        wk_b = params["wk_b"].astype(dtype).reshape(r_kv, h, hd)
        # Absorb W_uk into q: (B,1,H,hd) x (r,H,hd) -> (B,1,H,r)
        q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, wk_b)
        s_lat = jnp.einsum(
            "bshr,bcr->bshc", q_lat.astype(jnp.float32),
            ckv_r.astype(jnp.float32),
        )
        s_rope = jnp.einsum(
            "bshr,bcr->bshc", q_rope.astype(jnp.float32),
            rope_r.astype(jnp.float32),
        )
        logits = (s_lat + s_rope) * scale  # (B,1,H,C)
        mask = _band_mask(positions, pos_r, cfg.sliding_window)  # (B, 1, C)
        logits = jnp.where(mask[:, :, None, :], logits, NEG_INF)
        p = jax.nn.softmax(logits, axis=-1)
        o_lat = jnp.einsum("bshc,bcr->bshr", p, ckv_r.astype(jnp.float32))
        wv_b = params["wv_b"].astype(dtype).reshape(r_kv, h, hd)
        out = jnp.einsum("bshr,rhd->bshd", o_lat.astype(dtype), wv_b)
        new_cache = cache

    out = out.reshape(b, s, h * hd)
    return dense(params["wo"], out, dtype), new_cache
