"""Mamba2 (SSD — state-space duality) block [arXiv:2405.21060].

Train/prefill uses the chunked SSD algorithm (matmul-rich, MXU-friendly —
this is the TPU adaptation of the paper's GPU scan: work is blocked into
(chunk x chunk) decay matmuls instead of a warp-level scan).  Decode uses
the O(1) recurrent step on the cached state.

Block structure (Mamba2):
    in_proj -> [z | xBC | dt]; causal depthwise conv over xBC;
    SSD(x * dt, A * dt, B, C) + D skip; RMSNorm(y * silu(z)); out_proj.

State cache for decode:
    conv: (B, W-1, conv_dim)  last inputs of the depthwise conv window
    ssm:  (B, H, P, N)        the SSM state
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import ops as kernel_ops
from repro.models.layers import dense, dense_init, rmsnorm

__all__ = ["mamba_init", "mamba_apply", "init_ssm_state", "ssd_chunked", "ssd_step"]

Params = dict


def _dims(cfg: ModelConfig):
    inner = cfg.ssm_inner
    h = cfg.ssm_num_heads or inner // cfg.ssm_head_dim
    p = inner // h
    n = cfg.ssm_state_dim
    g = cfg.ssm_num_groups
    conv_dim = inner + 2 * g * n
    return inner, h, p, n, g, conv_dim


def mamba_init(key, cfg: ModelConfig) -> Params:
    inner, h, p, n, g, conv_dim = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    # A init in [1, 16) as in the reference implementation.
    a = jnp.exp(
        jax.random.uniform(ks[2], (h,), jnp.float32, np.log(1.0), np.log(16.0))
    )
    # The fused in_proj of the reference impl is split into three separately
    # shardable projections: z and xBC shard over the model axis; dt (H) is
    # tiny and stays replicated.
    return {
        "w_z": dense_init(ks[0], d, inner),
        "w_xbc": dense_init(ks[4], d, conv_dim),
        "w_dt": dense_init(ks[5], d, h),
        "conv_w": 0.1
        * jax.random.normal(ks[1], (cfg.ssm_conv_width, conv_dim), jnp.float32),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(a),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.exp(jax.random.uniform(
            ks[3], (h,), jnp.float32, np.log(1e-3), np.log(1e-1))))),
        "norm_scale": jnp.ones((inner,), jnp.float32),
        "out_proj": dense_init(jax.random.fold_in(key, 7), inner, d),
    }


def init_ssm_state(batch: int, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    inner, h, p, n, g, conv_dim = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, h, p, n), jnp.float32),
        "length": jnp.zeros((), jnp.int32),
    }


def _segsum(a: jax.Array) -> jax.Array:
    """(..., L) -> (..., L, L) with out[i, j] = sum_{k=j+1..i} a_k (i >= j),
    -inf above the diagonal.  exp() of this is the decay matrix."""
    l = a.shape[-1]
    c = jnp.cumsum(a, axis=-1)
    diff = c[..., :, None] - c[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,  # (B, L, H, P) already multiplied by dt
    a: jax.Array,  # (B, L, H)    log-decay per step (dt * A, negative)
    b_mat: jax.Array,  # (B, L, G, N)
    c_mat: jax.Array,  # (B, L, G, N)
    chunk: int,
    h0: jax.Array | None = None,  # (B, H, P, N) initial state
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.  Returns (y (B,L,H,P), final state (B,H,P,N))."""
    bsz, l, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    pad = (-l) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = x.shape[1] // chunk
    rep = h // g  # heads per B/C group

    xc = x.reshape(bsz, nc, chunk, h, p).astype(jnp.float32)
    ac = a.reshape(bsz, nc, chunk, h).astype(jnp.float32)
    bc = b_mat.reshape(bsz, nc, chunk, g, n).astype(jnp.float32)
    cc = c_mat.reshape(bsz, nc, chunk, g, n).astype(jnp.float32)
    bh = jnp.repeat(bc, rep, axis=3)  # (B,nc,L,H,N) — broadcast group to heads
    ch = jnp.repeat(cc, rep, axis=3)

    # Intra-chunk (diagonal blocks): Y = (C B^T  *  decay) X
    lmat = jnp.exp(_segsum(ac.transpose(0, 1, 3, 2)))  # (B,nc,H,L,L)
    scores = jnp.einsum("bclhn,bcshn->bchls", ch, bh)
    y_diag = jnp.einsum("bchls,bcshp->bclhp", scores * lmat, xc)

    # Chunk-final states: sum_s exp(sum_{k>s} a) B_s x_s
    a_cum = jnp.cumsum(ac, axis=2)  # (B,nc,L,H)
    decay_to_end = jnp.exp(a_cum[:, :, -1:, :] - a_cum)  # (B,nc,L,H)
    states = jnp.einsum("bclhn,bclh,bclhp->bchpn", bh, decay_to_end, xc)

    # Inter-chunk recurrence over chunk states.
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])  # (B,nc,H)

    def scan_fn(h_prev, inp):
        dec, st = inp  # (B,H), (B,H,P,N)
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev  # emit the state *entering* the chunk

    h_init = (
        h0.astype(jnp.float32)
        if h0 is not None
        else jnp.zeros((bsz, h, p, n), jnp.float32)
    )
    h_last, h_enter = jax.lax.scan(
        scan_fn,
        h_init,
        (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)),
    )
    h_enter = h_enter.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N)

    # Off-diagonal contribution: C_t  decay(t)  h_enter
    in_decay = jnp.exp(a_cum)  # (B,nc,L,H)
    y_off = jnp.einsum("bclhn,bclh,bchpn->bclhp", ch, in_decay, h_enter)

    y = (y_diag + y_off).reshape(bsz, nc * chunk, h, p)[:, :l]
    return y, h_last


def ssd_step(
    h_state: jax.Array,  # (B, H, P, N)
    x: jax.Array,  # (B, H, P)  dt-scaled input
    a: jax.Array,  # (B, H)     dt * A (negative)
    b_vec: jax.Array,  # (B, G, N)
    c_vec: jax.Array,  # (B, G, N)
) -> tuple[jax.Array, jax.Array]:
    """One recurrent step: h' = e^a h + x (x) B ; y = h' . C."""
    bsz, h, p, n = h_state.shape
    g = b_vec.shape[1]
    rep = h // g
    bh = jnp.repeat(b_vec, rep, axis=1)  # (B,H,N)
    ch = jnp.repeat(c_vec, rep, axis=1)
    h_new = h_state * jnp.exp(a)[..., None, None] + jnp.einsum(
        "bhp,bhn->bhpn", x.astype(jnp.float32), bh.astype(jnp.float32)
    )
    y = jnp.einsum("bhpn,bhn->bhp", h_new, ch.astype(jnp.float32))
    return y, h_new


def mamba_apply(
    params: Params,
    x: jax.Array,  # (B, S, d_model)
    cfg: ModelConfig,
    state: Params | None = None,
    *,
    return_state: bool = False,
    rows: jax.Array | None = None,  # (Bsub,) survivor rows (decode only)
    use_kernels: bool = False,  # decode: dispatch to the Pallas ssd_update
) -> tuple[jax.Array, Params | None]:
    """state=None: chunked scan over the sequence (train/prefill).
    state given: S must be 1 (decode) — O(1) recurrent update.

    ``rows``: x is a compacted survivor sub-batch; row ``i`` updates row
    ``rows[i]`` of the full-batch recurrent state (other rows untouched).
    With ``s > 1`` (continuous-batching admission) x is a block of newly
    admitted prompts: the scan starts from a *fresh zero* state — exactly
    a solo prefill — and the resulting conv window / SSM state scatter
    into rows ``rows`` of the resident state in place.

    ``use_kernels`` (decode only): the recurrent step runs in the Pallas
    ssd_update kernel, which reads the survivor rows of the full-batch
    resident SSM state through a scalar-prefetched row map (no gather
    copy) — the tiny conv window still gathers in jnp."""
    inner, h, p, n, g, conv_dim = _dims(cfg)
    bsz, s, _ = x.shape
    dtype = x.dtype
    w = cfg.ssm_conv_width

    full_state = state
    prefill_rows = rows is not None and s > 1
    if rows is not None and not prefill_rows:
        assert state is not None, "rows needs a resident state"
        state = {
            "conv": state["conv"][rows],
            # The kernel path reads its rows of the resident state in
            # place (scalar prefetch) — no gather; jnp gathers here.
            "ssm": state["ssm"] if use_kernels else state["ssm"][rows],
            "length": state["length"],
        }
    elif prefill_rows:
        # Row-targeted prompt prefill: the admitted rows' recurrence starts
        # from a fresh zero state (solo-prefill semantics); the final state
        # scatters into the resident rows below.
        assert state is not None, "rows needs a resident state"
        state = None

    z = dense(params["w_z"], x, dtype)
    xbc = dense(params["w_xbc"], x, dtype)
    dt_raw = dense(params["w_dt"], x, dtype)  # (B,S,H)
    raw_xbc = xbc  # pre-conv inputs, needed to seed the decode conv window

    new_state = None
    if (state is not None or prefill_rows) and s > 1:
        # Prefill with state write-through.
        return_state = True
    if state is None or s > 1:
        # Causal depthwise conv via explicit left padding.
        xbc_pad = jnp.pad(xbc, ((0, 0), (w - 1, 0), (0, 0)))
        windows = jnp.stack(
            [xbc_pad[:, i : i + s, :] for i in range(w)], axis=2
        )  # (B,S,W,C)
        xbc = jnp.einsum("bswc,wc->bsc", windows, params["conv_w"].astype(dtype))
        xbc = jax.nn.silu(xbc + params["conv_b"].astype(dtype))
    else:
        assert s == 1
        conv_in = jnp.concatenate([state["conv"].astype(dtype), xbc], axis=1)
        xbc = jnp.einsum(
            "bwc,wc->bc", conv_in, params["conv_w"].astype(dtype)
        )[:, None, :]
        xbc = jax.nn.silu(xbc + params["conv_b"].astype(dtype))
        new_conv = conv_in[:, 1:, :]

    xs = xbc[..., :inner].reshape(bsz, s, h, p)
    b_mat = xbc[..., inner : inner + g * n].reshape(bsz, s, g, n)
    c_mat = xbc[..., inner + g * n :].reshape(bsz, s, g, n)

    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"]
    )  # (B,S,H)
    a_neg = -jnp.exp(params["A_log"])  # (H,)
    x_dt = xs.astype(jnp.float32) * dt[..., None]
    a_dt = dt * a_neg  # (B,S,H)

    if state is None or s > 1:
        h0 = state["ssm"] if state is not None else None
        y, h_last = ssd_chunked(x_dt, a_dt, b_mat, c_mat, cfg.ssm_chunk, h0=h0)
        if return_state:
            # Raw (pre-conv) xBC inputs of the last W-1 positions seed the
            # decode-time conv window; left-pad if the sequence was shorter.
            conv_tail = jnp.pad(
                raw_xbc, ((0, 0), (max(0, (w - 1) - s), 0), (0, 0))
            )[:, -(w - 1) :, :]
            if prefill_rows:
                # Scatter the admitted rows into the resident state; the
                # resident step counter is untouched (mode="drop" skips
                # admission-group padding rows' OOB sentinels).
                new_state = {
                    "conv": full_state["conv"].at[rows].set(
                        conv_tail.astype(full_state["conv"].dtype), mode="drop"
                    ),
                    "ssm": full_state["ssm"].at[rows].set(h_last, mode="drop"),
                    "length": full_state["length"],
                }
            else:
                prev = (
                    state["length"] if state is not None
                    else jnp.asarray(0, jnp.int32)
                )
                new_state = {
                    "conv": conv_tail,
                    "ssm": h_last,
                    "length": prev + s,
                }
    else:
        if use_kernels:
            # Pallas single-step SSD update; with ``rows`` the full
            # resident state goes in and the kernel DMAs only those rows.
            y1, h_new = kernel_ops.ssd_update(
                state["ssm"], x_dt[:, 0], a_dt[:, 0],
                b_mat[:, 0], c_mat[:, 0], rows,
            )
        else:
            y1, h_new = ssd_step(
                state["ssm"], x_dt[:, 0], a_dt[:, 0], b_mat[:, 0], c_mat[:, 0]
            )
        y = y1[:, None]
        if rows is None:
            new_state = {
                "conv": new_conv,
                "ssm": h_new,
                "length": state["length"] + 1,
            }
        else:  # scatter the sub-batch update back into the full-batch state
            # (mode="drop": exited padding rows carry an OOB sentinel)
            new_state = {
                "conv": full_state["conv"].at[rows].set(
                    new_conv.astype(full_state["conv"].dtype), mode="drop"
                ),
                "ssm": full_state["ssm"].at[rows].set(h_new, mode="drop"),
                "length": full_state["length"] + 1,
            }

    y = y + xs.astype(jnp.float32) * params["D"][:, None]
    y = y.reshape(bsz, s, inner).astype(dtype)
    y = rmsnorm({"scale": params["norm_scale"]}, y * jax.nn.silu(z))
    return dense(params["out_proj"], y, dtype), new_state
