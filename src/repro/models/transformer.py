"""Block assembly and layer-stack execution.

A *block* is one residual trunk layer: a mixer (GQA / MLA / Mamba2 / none)
plus an MLP (dense / MoE / none), each behind a pre-norm.  Blocks of the
same *kind* are stacked along a leading layer axis and executed with
``jax.lax.scan`` so 61–80-layer models compile as one program regardless of
depth (critical for the 512-device dry-run).

The trunk is segmented at *stop points* (side-branch positions, hybrid
shared-attention sites, the partition layer): each segment is its own scan
over a static slice of the stacked params.  This is exactly the structure
the paper's partitioner needs — the edge runs a prefix of segments, ships
the residual stream, and the cloud runs the rest.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models.layers import mlp_apply, mlp_init, norm_apply, norm_init
from repro.sharding.ctx import constrain

__all__ = [
    "BlockKind",
    "block_init",
    "block_apply",
    "stack_init",
    "stack_slice",
    "run_stack",
    "init_block_cache",
]

Params = dict


@dataclasses.dataclass(frozen=True)
class BlockKind:
    mixer: str  # "gqa" | "mla" | "mamba" | "none"
    mlp: str  # "dense" | "moe" | "none"
    cross_attention: bool = False  # whisper decoder
    causal: bool = True  # False for encoder blocks
    use_rope: bool = True


def block_init(key, cfg: ModelConfig, kind: BlockKind) -> Params:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    p: Params = {}
    if kind.mixer == "gqa":
        p["norm1"] = norm_init(cfg.norm_type, d)
        p["attn"] = attn_mod.attn_init(ks[0], cfg)
    elif kind.mixer == "mla":
        p["norm1"] = norm_init(cfg.norm_type, d)
        p["attn"] = attn_mod.mla_init(ks[0], cfg)
    elif kind.mixer == "mamba":
        p["norm1"] = norm_init(cfg.norm_type, d)
        p["mamba"] = mamba_mod.mamba_init(ks[0], cfg)
    if kind.cross_attention:
        p["norm_x"] = norm_init(cfg.norm_type, d)
        p["xattn"] = attn_mod.attn_init(ks[1], cfg)
    if kind.mlp == "dense":
        p["norm2"] = norm_init(cfg.norm_type, d)
        p["mlp"] = mlp_init(ks[2], d, cfg.d_ff, cfg.mlp_type)
    elif kind.mlp == "moe":
        p["norm2"] = norm_init(cfg.norm_type, d)
        p["moe"] = moe_mod.moe_init(ks[2], cfg)
    return p


def init_block_cache(
    batch: int, capacity: int, cfg: ModelConfig, kind: BlockKind, dtype=jnp.bfloat16
):
    """Decode-time cache for one block (None if the block is stateless)."""
    cache: dict[str, Any] = {}
    if kind.mixer == "gqa":
        cache["self"] = attn_mod.init_kv_cache(
            batch, capacity, cfg.num_kv_heads, cfg.head_dim, dtype
        )
    elif kind.mixer == "mla":
        cache["self"] = attn_mod.init_mla_cache(batch, capacity, cfg, dtype)
    elif kind.mixer == "mamba":
        cache["self"] = mamba_mod.init_ssm_state(batch, cfg)
    return cache


def block_apply(
    params: Params,
    h: jax.Array,
    cfg: ModelConfig,
    kind: BlockKind,
    positions: jax.Array,
    cache: Params | None = None,
    cross_kv: tuple[jax.Array, jax.Array] | None = None,
    moe_dispatch: str = "einsum",
    rows: jax.Array | None = None,
    use_kernels: bool = False,
) -> tuple[jax.Array, Params | None, jax.Array]:
    """Returns (h, new_cache, aux_loss).

    ``rows`` (decode only): h is a compacted survivor sub-batch; stateful
    ops read/write rows ``rows`` of the full-batch cache/state.

    ``use_kernels`` (decode only): GQA attention and Mamba2 recurrent
    updates dispatch to the Pallas kernels (flash_decode / ssd_update);
    MLA's absorbed-latent decode and cross-attention stay on the jnp
    path (no kernel variant)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: dict[str, Any] = {}
    window = cfg.sliding_window

    if kind.mixer in ("gqa", "mla"):
        hn = norm_apply(cfg.norm_type, params["norm1"], h)
        sa_cache = cache.get("self") if cache else None
        if kind.mixer == "gqa":
            y, c = attn_mod.attn_apply(
                params["attn"], hn, cfg, positions, sa_cache,
                use_rope=kind.use_rope,
                window=window if kind.causal else 0,
                rows=rows if sa_cache is not None else None,
                use_kernels=use_kernels and sa_cache is not None,
            )
        else:
            y, c = attn_mod.mla_apply(
                params["attn"], hn, cfg, positions, sa_cache,
                rows=rows if sa_cache is not None else None,
            )
        h = h + y
        if c is not None:
            new_cache["self"] = c
    elif kind.mixer == "mamba":
        hn = norm_apply(cfg.norm_type, params["norm1"], h)
        y, c = mamba_mod.mamba_apply(
            params["mamba"], hn, cfg,
            state=cache.get("self") if cache else None,
            rows=rows if cache else None,
            use_kernels=use_kernels and cache is not None,
        )
        h = h + y
        if c is not None:
            new_cache["self"] = c

    if kind.cross_attention and cross_kv is not None:
        if rows is not None:
            # Compacted sub-batch: cross K/V rows follow the survivors.
            cross_kv = (cross_kv[0][rows], cross_kv[1][rows])
        hn = norm_apply(cfg.norm_type, params["norm_x"], h)
        y, _ = attn_mod.attn_apply(
            params["xattn"], hn, cfg, positions, None,
            use_rope=False, window=0, kv_override=cross_kv,
        )
        h = h + y

    if kind.mlp == "dense":
        hn = norm_apply(cfg.norm_type, params["norm2"], h)
        h = h + mlp_apply(params["mlp"], hn, cfg.mlp_type)
    elif kind.mlp == "moe":
        hn = norm_apply(cfg.norm_type, params["norm2"], h)
        y, aux_moe = moe_mod.moe_apply(
            params["moe"], hn, cfg, dispatch=moe_dispatch
        )
        h = h + y
        aux = aux + aux_moe

    return h, (new_cache if new_cache else None), aux


def stack_init(key, cfg: ModelConfig, n_layers: int, kind: BlockKind) -> Params:
    """Stacked params: every leaf gains a leading (n_layers,) axis."""
    keys = jax.random.split(key, n_layers)
    return jax.vmap(lambda k: block_init(k, cfg, kind))(keys)


def stack_slice(stacked: Params, lo: int, hi: int) -> Params:
    return jax.tree_util.tree_map(lambda a: a[lo:hi], stacked)


def run_stack(
    stacked_params: Params,
    h: jax.Array,
    cfg: ModelConfig,
    kind: BlockKind,
    positions: jax.Array,
    caches: Params | None = None,  # stacked along layer axis
    cross_kv: tuple[jax.Array, jax.Array] | None = None,  # stacked (L, B, S, K, D)
    *,
    remat: bool = False,
    moe_dispatch: str = "einsum",
    rows: jax.Array | None = None,
    use_kernels: bool = False,
) -> tuple[jax.Array, Params | None, jax.Array]:
    """Scan the blocks of a (slice of a) stack over the residual stream.

    Returns (h, new stacked caches, summed aux loss).  ``rows`` threads the
    survivor-compaction row map into every stateful block (decode only);
    ``use_kernels`` dispatches each stateful block's decode math to the
    Pallas kernels.
    """

    if caches is None:
        # Stateless (training / cache-free prefill): params (+cross KV) are
        # scan inputs; nothing is carried but the residual stream.
        def body(carry, xs):
            h = carry
            lparams, lcross = xs
            h, _, aux = block_apply(
                lparams, h, cfg, kind, positions, None, lcross,
                moe_dispatch=moe_dispatch,
            )
            if cfg.seq_shard_activations:
                # The remat-saved per-layer carry is seq-sharded over the
                # model axis (sequence parallelism); compute re-gathers.
                h = constrain(h, "bv.")
            return h, aux

        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        # `None` is an empty pytree: scan broadcasts it per step unchanged.
        h, auxes = jax.lax.scan(body, h, (stacked_params, cross_kv))
        return h, None, jnp.sum(auxes)

    # Stateful (decode / cache-writing prefill): the FULL stacked cache is a
    # loop carry updated in place at the layer index — this lets XLA alias
    # the cache buffers instead of double-buffering a scan ys output (which
    # costs ~2x cache HBM at 32k contexts; see EXPERIMENTS §Perf).
    def body_cache(carry, xs):
        h, cache_full, i = carry
        lparams, lcross = xs
        lcache = jax.tree_util.tree_map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
            cache_full,
        )
        h, new_cache, aux = block_apply(
            lparams, h, cfg, kind, positions, lcache, lcross,
            moe_dispatch=moe_dispatch, rows=rows, use_kernels=use_kernels,
        )
        cache_full = jax.tree_util.tree_map(
            lambda full, one: jax.lax.dynamic_update_index_in_dim(
                full, one.astype(full.dtype), i, 0
            ),
            cache_full, new_cache,
        )
        return (h, cache_full, i + 1), aux

    if remat:
        body_cache = jax.checkpoint(body_cache, prevent_cse=False)
    (h, new_caches, _), auxes = jax.lax.scan(
        body_cache, (h, caches, jnp.zeros((), jnp.int32)), (stacked_params, cross_kv)
    )
    return h, new_caches, jnp.sum(auxes)
