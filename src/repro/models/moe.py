"""Mixture-of-Experts: top-k router + capacity-based dispatch/combine.

Two dispatch strategies, selectable at call time:

  * ``"einsum"`` — GShard/MaxText-style dense dispatch: a one-hot
    (groups, tokens, experts, capacity) tensor contracted against the
    activations.  Sharding-friendly (the expert axis lives on "model" and
    XLA SPMD inserts the all-to-alls), but the dispatch einsums burn real
    FLOPs — visible in the roofline's MODEL_FLOPS/HLO_FLOPs ratio.  This is
    the baseline.
  * ``"onehot_small"`` — same math with the dispatch tensor kept in the
    minimal integer form and contracted via take/segment_sum.  Fewer FLOPs,
    gather/scatter instead; used by the perf pass (EXPERIMENTS §Perf).

Tokens are processed in fixed-size groups (GSPMD-friendly static shapes);
per-group expert capacity C = ceil(group_tokens * top_k * capacity_factor /
num_experts).  Overflowing tokens are dropped (their combine weight is zero
and the residual path carries them), the standard "token dropping" regime.

Router auxiliary load-balance loss follows Switch/GShard:
    aux = num_experts * sum_e (frac_tokens_e * mean_gate_e).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import dense, dense_init
from repro.sharding.ctx import constrain

__all__ = ["moe_init", "moe_apply", "router_topk"]

Params = dict


def moe_init(key, cfg: ModelConfig) -> Params:
    """Stacked expert weights: (E, d, ff) so the expert axis shards."""
    ks = jax.random.split(key, 7)
    d, ff, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    scale = 1.0 / np.sqrt(d)
    p = {
        "router": dense_init(ks[0], d, e, scale=0.02),
        "w_gate": scale * jax.random.truncated_normal(ks[1], -2, 2, (e, d, ff), jnp.float32),
        "w_up": scale * jax.random.truncated_normal(ks[2], -2, 2, (e, d, ff), jnp.float32),
        "w_down": (1.0 / np.sqrt(ff))
        * jax.random.truncated_normal(ks[3], -2, 2, (e, ff, d), jnp.float32),
    }
    if cfg.num_shared_experts:
        sff = ff * cfg.num_shared_experts
        p["shared"] = {
            "w_gate": dense_init(ks[4], d, sff),
            "w_up": dense_init(ks[5], d, sff),
            "w_down": dense_init(ks[6], sff, d),
        }
    return p


def router_topk(
    logits: jax.Array, top_k: int
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Softmax-then-topk routing (DeepSeek-V3 normalizes over the selected
    experts; we renormalize the top-k mass which matches both it and Qwen3).

    Returns (weights (..., top_k), indices (..., top_k), aux_loss scalar).
    """
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, idx = jax.lax.top_k(probs, top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    e = logits.shape[-1]
    # Load-balance aux: fraction routed to e  x  mean router prob of e.
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)  # (..., top_k, E)
    frac = onehot.sum(axis=tuple(range(onehot.ndim - 1))) / (
        np.prod(onehot.shape[:-2]) * 1.0
    )
    mean_prob = probs.reshape(-1, e).mean(0)
    aux = e * jnp.sum(frac / top_k * mean_prob)
    return w.astype(logits.dtype), idx, aux


def _experts_ffn(p: Params, x_e: jax.Array, dtype) -> jax.Array:
    """Per-expert SwiGLU on (E, C', d) -> (E, C', d)."""
    g = jnp.einsum("ecd,edf->ecf", x_e, p["w_gate"].astype(dtype))
    u = jnp.einsum("ecd,edf->ecf", x_e, p["w_up"].astype(dtype))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["w_down"].astype(dtype))


def moe_apply(
    params: Params,
    x: jax.Array,  # (B, S, d)
    cfg: ModelConfig,
    *,
    group_size: int = 256,
    dispatch: str = "auto",
) -> tuple[jax.Array, jax.Array]:
    """Returns (output (B, S, d), router aux loss).

    dispatch="auto" picks einsum (GShard-faithful) while the dense dispatch
    tensor stays small, and falls back to the gather/scatter form when it
    would not (prefill-scale MoE: tokens * group * top_k * cf bytes explode;
    the switch is the shape-dependent algorithm choice a production system
    makes — both paths are numerically equivalent, tests assert it).
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    dtype = x.dtype
    if dispatch == "auto":
        # Per-device dense-dispatch footprint on the canonical 16x16 mesh
        # (G over data, E over model): tokens * gsz * topk * cf * 2B / 256.
        tokens_total = b * s
        disp_bytes = (
            tokens_total * min(group_size, tokens_total) * k
            * cfg.capacity_factor * 2 / 256
        )
        dispatch = "einsum" if disp_bytes <= 2e9 else "onehot_small"

    tokens = x.reshape(b * s, d)
    t = tokens.shape[0]
    gsz = min(group_size, t)
    pad = (-t) % gsz
    if pad:
        tokens = jnp.pad(tokens, ((0, pad), (0, 0)))
    ng = tokens.shape[0] // gsz
    xg = tokens.reshape(ng, gsz, d)

    logits = dense(params["router"], xg, dtype)  # (G, T, E)
    w, idx, aux = router_topk(logits, k)  # (G,T,k), (G,T,k)

    cap = int(np.ceil(gsz * k * cfg.capacity_factor / e))
    cap = max(cap, 1)

    # Position of each (token, choice) within its expert's capacity buffer.
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)  # (G,T,k,E)
    flat = onehot.reshape(ng, gsz * k, e)
    pos_in_e = jnp.cumsum(flat, axis=1) - 1  # (G, T*k, E)
    pos = (pos_in_e * flat).sum(-1).reshape(ng, gsz, k)  # (G,T,k)
    keep = pos < cap
    w = jnp.where(keep, w, 0.0)

    if dispatch == "einsum":
        # (G, T, k, E, C) one-hot dispatch/combine, contracted densely.
        disp = (
            jax.nn.one_hot(idx, e, dtype=dtype)[..., None]
            * jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1, dtype=dtype)[
                ..., None, :
            ]
        )[..., :cap]  # (G,T,k,E,C) — slot `cap` is the drop bucket
        disp_sum = disp.sum(2)  # (G,T,E,C)
        x_e = jnp.einsum("gtec,gtd->gecd", disp_sum, xg)  # all-to-all here
        # Pin the expert axis to "model" so the per-expert FFN runs
        # expert-parallel instead of batch-replicated (no-op without an
        # active mesh context).
        x_e = constrain(x_e, ".v..")
        y_e = jax.vmap(lambda xe: _experts_ffn(params, xe, dtype))(x_e)
        comb = (disp * w[..., None, None]).sum(2)  # (G,T,E,C)
        yg = jnp.einsum("gtec,gecd->gtd", comb, y_e)
    elif dispatch == "onehot_small":
        # Gather/scatter form: build (E, C) token indices per group.
        def per_group(xg1, idx1, pos1, keep1, w1):
            # slot owner: for each (e, c), which token filled it (or -1).
            tok_ids = jnp.arange(gsz)[:, None].repeat(k, 1)  # (T,k)
            slot = jnp.where(keep1, pos1, cap)  # (T,k)
            owner = jnp.full((e, cap + 1), gsz, jnp.int32)  # gsz = pad token
            owner = owner.at[idx1.reshape(-1), slot.reshape(-1)].set(
                tok_ids.reshape(-1), mode="drop"
            )[:, :cap]
            xg_pad = jnp.concatenate([xg1, jnp.zeros((1, d), xg1.dtype)], 0)
            x_e = xg_pad[owner]  # (E, C, d)
            y_e = _experts_ffn(params, x_e, dtype)
            # combine: each token sums its surviving choices.
            gathered = y_e[idx1, jnp.where(keep1, pos1, 0)]  # (T,k,d)
            return (gathered * w1[..., None]).sum(1)

        yg = jax.vmap(per_group)(xg, idx, pos, keep, w)
    else:
        raise ValueError(dispatch)

    y = constrain(yg.reshape(-1, d)[:t].reshape(b, s, d), "b..")
    if cfg.num_shared_experts:
        sp = params["shared"]
        g = dense(sp["w_gate"], x, dtype)
        u = dense(sp["w_up"], x, dtype)
        y = y + dense(sp["w_down"], jax.nn.silu(g) * u, dtype)
    return y, aux
