"""repro.data — see module docstrings."""
