"""Synthetic data pipeline.

Deterministic, infinite, steppable token streams for training and serving
benchmarks, plus the distortion transforms the calibration experiment needs
(the paper blurs images to move branch entropy — we add Gaussian noise to
embeddings/logit temperature, the LM analog; Fig. 6 reproduction).

A real deployment would swap `SyntheticLM` for a tokenized corpus reader;
the interface (``__iter__`` of pytrees with a leading batch dim) is the
contract the train loop consumes.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

__all__ = ["SyntheticLM", "make_batch", "distort_embeddings", "DistortionLevel"]


@dataclasses.dataclass(frozen=True)
class DistortionLevel:
    """Analog of the paper's Gaussian-blur severities (Sec. VI, Fig. 6)."""

    name: str
    noise_std: float


DISTORTIONS = {
    "low": DistortionLevel("low", 0.1),
    "mid": DistortionLevel("mid", 0.5),
    "high": DistortionLevel("high", 2.0),
}


def make_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 0) -> dict:
    """One batch of the shape forward_train expects, on CPU numpy."""
    rng = np.random.default_rng(seed)
    out: dict = {}
    if cfg.frontend == "vision":
        text = seq - cfg.num_patches
        out["tokens"] = rng.integers(0, cfg.vocab_size, (batch, text), dtype=np.int32)
        out["patch_embeds"] = rng.normal(0, 1, (batch, cfg.num_patches, cfg.d_model)).astype(
            np.float32
        )
        out["labels"] = out["tokens"]
    elif cfg.frontend == "audio":
        out["tokens"] = rng.integers(0, cfg.vocab_size, (batch, seq), dtype=np.int32)
        out["frame_embeds"] = rng.normal(
            0, 1, (batch, cfg.encoder_seq_len, cfg.d_model)
        ).astype(np.float32)
        out["labels"] = out["tokens"]
    else:
        # Markov-ish synthetic text: mixture of a few token patterns so the
        # loss actually decreases during the example training runs.
        base = rng.integers(0, cfg.vocab_size, (batch, seq), dtype=np.int32)
        period = max(2, cfg.vocab_size // 97)
        pattern = (np.arange(seq)[None, :] * 7 + rng.integers(0, period, (batch, 1))) % min(
            97, cfg.vocab_size
        )
        use_pat = rng.random((batch, seq)) < 0.7
        out["tokens"] = np.where(use_pat, pattern, base).astype(np.int32)
        out["labels"] = out["tokens"]
    return out


@dataclasses.dataclass
class SyntheticLM:
    cfg: ModelConfig
    batch: int
    seq: int
    seed: int = 0

    def __iter__(self) -> Iterator[dict]:
        i = 0
        while True:
            yield make_batch(self.cfg, self.batch, self.seq, self.seed + i)
            i += 1


def distort_embeddings(key, embeds: jax.Array, level: DistortionLevel) -> jax.Array:
    """The paper's image-quality knob, applied to the embedding stub:
    heavier noise -> flatter branch posteriors -> lower exit probability."""
    noise = jax.random.normal(key, embeds.shape, jnp.float32) * level.noise_std
    return embeds + noise.astype(embeds.dtype)
