"""K-tier partitioning (beyond-paper, DESIGN.md Sec. 7).

The paper splits across two tiers (edge, cloud).  Real fleets have more:
end device -> edge server -> regional cloud -> core cloud, with a bandwidth
cliff at every hop.  The same shortest-path insight generalizes: execution
is monotone through tiers (layers only move forward), so the optimal
assignment is a monotone non-decreasing map layer->tier, i.e. a path in a
layered (layer x tier) lattice:

    state (i, k): layers 1..i done, currently on tier k
    stay:  (i, k) -> (i+1, k)   cost surv(i) * t_{i+1}^k
    hop:   (i, k) -> (i, k+1)   cost surv(i) * alpha_i / B_k
    exits: side branches scale everything downstream by (1 - p_b), exactly
           as in the 2-tier model (evaluated on whichever tier holds them).

Solved by DP over the lattice (topological order), O(N * K).
With K == 2 this reduces to the paper's problem; tests assert agreement.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.types import CostProfile

__all__ = [
    "TierSpec",
    "MultiTierPlan",
    "solve_multitier",
    "expected_time_multitier",
]


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One tier: per-layer compute times and uplink bandwidth to the NEXT
    tier (bits/s; last tier's uplink is unused)."""

    name: str
    gamma: float  # t_i at this tier = gamma * t_c (paper's convention)
    uplink_bps: float = 0.0


@dataclasses.dataclass(frozen=True)
class MultiTierPlan:
    cut_after: tuple[int, ...]  # layer after which each hop happens (K-1,)
    expected_time_s: float
    tier_of_layer: tuple[int, ...]  # (N,) tier index per layer


def solve_multitier(
    t_c: np.ndarray,  # (N+1,) cloud-reference per-layer times, [0] == 0
    alpha: np.ndarray,  # (N+1,) output bytes, [0] == raw input
    branch_probs: np.ndarray,  # (N+1,) conditional exit prob per layer
    tiers: list[TierSpec],
) -> MultiTierPlan:
    t_c = np.asarray(t_c, float)
    alpha = np.asarray(alpha, float)
    p = np.asarray(branch_probs, float)
    n = len(t_c) - 1
    k = len(tiers)
    assert k >= 1

    surv = np.cumprod(1.0 - p)  # surv[i] = alive after layer i's branch
    reach = np.concatenate([[1.0], surv[:-1]])  # alive entering layer i

    # Branch semantics (paper Sec. IV-B): side branches run on every tier
    # EXCEPT the last (the cloud evaluates none), and the branch sitting
    # exactly at a cut is discarded (Fig. 2(c)).  So on tiers 0..K-2 the
    # survival bookkeeping is the global reach[] array, and the last tier's
    # whole tail is frozen at the survival of the final hop.  (For K >= 3
    # this treats a branch at an *intermediate* hop as evaluated by the
    # next branchy tier — exact whenever no branch sits exactly at a cut.)
    last = k - 1
    # dist[i][j]: layers 1..i done on branchy tiers, currently on tier j<last.
    dist = np.full((n + 1, max(last, 1)), np.inf)
    parent = np.full((n + 1, max(last, 1), 2), -1, dtype=int)
    dist[0][0] = 0.0
    for j in range(1, last):
        cand = dist[0][j - 1] + alpha[0] * 8.0 / tiers[j - 1].uplink_bps
        if cand < dist[0][j]:
            dist[0][j] = cand
            parent[0][j] = (0, j - 1)
    for i in range(1, n + 1):
        for j in range(last):
            cand = dist[i - 1][j] + reach[i] * tiers[j].gamma * t_c[i]
            if cand < dist[i][j]:
                dist[i][j] = cand
                parent[i][j] = (i - 1, j)
        for j in range(1, last):
            cand = dist[i][j - 1] + reach[i] * alpha[i] * 8.0 / tiers[j - 1].uplink_bps
            if cand < dist[i][j]:
                dist[i][j] = cand
                parent[i][j] = (i, j - 1)

    # Closed-form frozen tail on the last tier (no branches there).
    tail = np.concatenate([np.cumsum(t_c[::-1])[::-1][1:], [0.0]])
    best_cost, best_i, end_on_last = np.inf, n, False
    if last >= 1:
        for j in range(last):
            if dist[n][j] < best_cost:  # finish without reaching the cloud
                best_cost, best_i, end_on_last = float(dist[n][j]), n, False
                best_j_final = j
        for i in range(0, n + 1):
            hop = dist[i][last - 1] + reach[i] * (
                alpha[i] * 8.0 / tiers[last - 1].uplink_bps
                + tiers[last].gamma * tail[i]
            )
            if hop < best_cost:
                best_cost, best_i, end_on_last = float(hop), i, True
                best_j_final = last - 1
    else:  # single tier: everything runs there
        best_cost = float(np.sum(reach[1:] * tiers[0].gamma * t_c[1:]))
        best_i, end_on_last, best_j_final = n, False, 0

    # Backtrack the branchy-tier assignment up to best_i.
    tier_of_layer = [last] * (n + 1)
    i, j = best_i, best_j_final
    while i > 0 or j > 0:
        pi, pj = parent[i][j]
        if pi < 0:
            break
        if pi == i - 1 and pj == j:
            tier_of_layer[i] = j
        i, j = int(pi), int(pj)
    cuts = []
    for j in range(1, k):
        after = max([i for i in range(1, n + 1) if tier_of_layer[i] < j],
                    default=0)
        cuts.append(after)
    return MultiTierPlan(
        cut_after=tuple(cuts),
        expected_time_s=best_cost,
        tier_of_layer=tuple(tier_of_layer[1:]),
    )


def expected_time_multitier(
    t_c: np.ndarray,
    alpha: np.ndarray,
    branch_probs: np.ndarray,
    tiers: list[TierSpec],
    cuts: tuple[int, ...],
) -> float:
    """Closed-form E[T] of one *fixed* monotone cut vector (the plan the
    runtime executes), same semantics as :func:`solve_multitier`: branches
    run on tiers 0..K-2 (reach-weighted), the last tier's tail is frozen at
    the wire survival, and a hop is charged iff layers still run after it.
    """
    t_c = np.asarray(t_c, float)
    alpha = np.asarray(alpha, float)
    p = np.asarray(branch_probs, float)
    n = len(t_c) - 1
    k = len(tiers)
    if len(cuts) != k - 1:
        raise ValueError(f"need {k - 1} cuts for {k} tiers, got {cuts}")
    bounds = (0, *(int(c) for c in cuts), n)
    if any(b > a for a, b in zip(bounds[1:], bounds[:-1])):
        raise ValueError(f"cuts must be non-decreasing in [0, {n}]: {cuts}")

    surv = np.cumprod(1.0 - p)
    reach = np.concatenate([[1.0], surv[:-1]])
    cost = 0.0
    for j in range(k):
        lo, hi = bounds[j], bounds[j + 1]
        for i in range(lo + 1, hi + 1):
            w = reach[bounds[k - 1]] if (j == k - 1 and k > 1) else reach[i]
            cost += w * tiers[j].gamma * t_c[i]
    for j in range(k - 1):
        c = bounds[j + 1]
        if c < n:  # layers still run downstream -> the hop really happens
            cost += reach[c] * alpha[c] * 8.0 / tiers[j].uplink_bps
    return float(cost)


def from_cost_profile(profile: CostProfile, tiers: list[TierSpec]) -> MultiTierPlan:
    return solve_multitier(
        profile.t_c, profile.alpha, profile.branch_exit_probs(), tiers
    )
