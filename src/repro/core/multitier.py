"""K-tier partitioning (beyond-paper, DESIGN.md Sec. 7).

The paper splits across two tiers (edge, cloud).  Real fleets have more:
end device -> edge server -> regional cloud -> core cloud, with a bandwidth
cliff at every hop.  The same shortest-path insight generalizes: execution
is monotone through tiers (layers only move forward), so the optimal
assignment is a monotone non-decreasing map layer->tier, i.e. a path in a
layered (layer x tier) lattice:

    state (i, k): layers 1..i done, currently on tier k
    stay:  (i, k) -> (i+1, k)   cost surv(i) * t_{i+1}^k
    hop:   (i, k) -> (i, k+1)   cost surv(i) * alpha_i / B_k
    exits: side branches scale everything downstream by (1 - p_b), exactly
           as in the 2-tier model (evaluated on whichever tier holds them).

Solved by DP over the lattice (topological order), O(N * K).
With K == 2 this reduces to the paper's problem; tests assert agreement.

Overlap (pipelined) mode.  The serial cost above is the latency of one
isolated sample: every stage waits for the previous one.  A pipelined
deployment (``overlap=True``) overlaps tier j's uplink transfer with tier
j+1's compute and double-buffers decode steps, so the *steady-state* cost
per step is the pipeline bottleneck stage

    max_j( compute_j, transfer_j )

rather than the serial sum — matching ``TierExecutor(overlap="pipelined")``.

Sharded tiers (``TierSpec.devices > 1``).  A tier that is a device *mesh*
rather than a chip computes each layer ``devices`` times faster but pays an
intra-tier collective per layer: a ring all-reduce of the layer's
activation (``alpha_i`` bytes) over the tier's ``ici_bps`` interconnect,
``_COLLECTIVES_PER_LAYER`` times per layer.  Both the enumeration and the
lattice DP price this through :func:`_tier_layer_seconds`, so the solver
can trade "shard tier j over d chips" against "add a hop" — the
generalization arXiv 2210.12219 argues for (per-device compute and
collective/hop traffic priced jointly).
Per-stage weights (reach / bucketed padding) are identical to serial mode;
only the aggregation changes.  A bottleneck is not edge-decomposable over
the lattice, so the overlap solve enumerates monotone cut vectors directly
(K keeps the combinatorics tiny); above ``_BUCKETED_ENUM_CAP`` candidates
it falls back to the serial DP's cuts re-scored under overlap (documented
approximation).
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Callable, Sequence

import numpy as np

from repro.core.types import CostProfile

__all__ = [
    "TierSpec",
    "MultiTierPlan",
    "solve_multitier",
    "expected_time_multitier",
    "bucket_ladder",
    "bucket_for",
]


# ------------------------------------------------------------ bucket ladder
def bucket_ladder(batch: int) -> tuple[int, ...]:
    """Static jit shapes the compacted runtime pads survivor sub-batches
    to: powers of two below ``batch``, plus ``batch`` itself (a no-exit
    step compacts through the identity permutation at full width)."""
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    out = []
    b = 1
    while b < batch:
        out.append(b)
        b *= 2
    out.append(batch)
    return tuple(out)


def bucket_for(n: int, batch: int) -> int:
    """Smallest ladder bucket that fits ``n`` survivors (min 1: even an
    all-exit step keeps one padding row downstream so per-layer cache
    write indices stay in lockstep across tiers)."""
    for b in bucket_ladder(batch):
        if b >= max(int(n), 1):
            return b
    return batch


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One tier: per-layer compute times, uplink bandwidth to the NEXT
    tier (bits/s; last tier's uplink is unused), and the tier's shard
    width.

    ``devices > 1`` models a tensor/expert-parallel tier (a pod slice,
    not a chip): per-layer compute scales ``1/devices``, and every layer
    pays an intra-tier collective term — a ring all-reduce of the layer's
    activation over ``ici_bps`` (bits/s of intra-tier interconnect),
    ``_COLLECTIVES_PER_LAYER`` times per layer.  An unset/zero ``ici_bps``
    with ``devices > 1`` prices the collectives infinite (the shards
    cannot reduce), mirroring :func:`_hop_seconds`'s dead-uplink policy.
    """

    name: str
    gamma: float  # t_i at this tier = gamma * t_c (paper's convention)
    uplink_bps: float = 0.0
    devices: int = 1  # shard width (tensor/expert-parallel fan-out)
    ici_bps: float = 0.0  # intra-tier interconnect (per-device, bits/s)
    #: Uplink health: the estimated probability a transfer over this
    #: tier's uplink succeeds (the controller feeds its EWMA of observed
    #: fault events here).  A flaky hop's expected cost scales
    #: ``1/availability`` (retries until success); ``availability <= 0``
    #: — a breaker-open link — prices the hop infinite, so the solver
    #: routes the cut around a sick link exactly as it routes around a
    #: dead one.
    availability: float = 1.0


#: All-reduces a sharded trunk layer pays on its activation (attention wo
#: partial-sum + MLP w_down partial-sum under Megatron-style sharding).
_COLLECTIVES_PER_LAYER = 2.0


def _collective_seconds(devices: int, bits: float, ici_bps: float) -> float:
    """Intra-tier ring all-reduce time for one layer's activation: each
    device moves ``2 * (d-1)/d * bits`` over its ICI link, twice per layer
    (see ``_COLLECTIVES_PER_LAYER``).  Free at devices==1 or zero bits;
    infinite over an unset interconnect (same policy as _hop_seconds)."""
    if devices <= 1 or bits <= 0.0:
        return 0.0
    if not ici_bps or ici_bps <= 0.0:
        return math.inf
    ring = 2.0 * (devices - 1) / devices
    return _COLLECTIVES_PER_LAYER * ring * bits / ici_bps


def _tier_layer_seconds(tier: TierSpec, t_c_i: float, alpha_i: float) -> float:
    """Unweighted seconds tier ``tier`` spends on one trunk layer: the
    paper's ``gamma * t_c`` scaled by the shard width, plus the sharded
    layer's collective term on its activation ``alpha_i`` bytes."""
    d = max(int(tier.devices), 1)
    t = tier.gamma * t_c_i / d
    if d > 1:
        t += _collective_seconds(d, alpha_i * 8.0, tier.ici_bps)
    return t


@dataclasses.dataclass(frozen=True)
class MultiTierPlan:
    cut_after: tuple[int, ...]  # layer after which each hop happens (K-1,)
    expected_time_s: float
    tier_of_layer: tuple[int, ...]  # (N,) tier index per layer


def _padded_frac(reach_i: float, batch: int) -> float:
    """Fraction of the full batch a downstream tier actually computes on:
    expected survivors rounded up to the runtime's bucket ladder."""
    n = int(np.ceil(reach_i * batch - 1e-9))
    return bucket_for(n, batch) / batch


def _hop_seconds(
    bits: float, uplink_bps: float, availability: float = 1.0
) -> float:
    """Transfer seconds for ``bits`` over a hop.  A hop that ships nothing
    is free; a hop that ships over an unset/zero uplink — or one whose
    estimated ``availability`` is zero (breaker open) — is unusable
    (infinite cost), never a ZeroDivisionError.  A flaky-but-alive hop
    costs ``1/availability`` times its raw transfer (expected attempts
    until one succeeds under i.i.d. failures)."""
    if bits <= 0.0:
        return 0.0
    if not uplink_bps or uplink_bps <= 0.0:
        return math.inf
    if availability <= 0.0:
        return math.inf
    return bits / uplink_bps / min(float(availability), 1.0)


def _infeasible_error(tiers: list[TierSpec]) -> ValueError:
    """Diagnostic for a profile with no finite-cost plan, naming the first
    unreachable tier when a dead uplink is the culprit."""
    dead = next(
        (j for j in range(len(tiers) - 1)
         if not tiers[j].uplink_bps or tiers[j].uplink_bps <= 0.0
         or tiers[j].availability <= 0.0),
        None,
    )
    detail = (
        f"tier {tiers[dead + 1].name!r} is unreachable "
        f"(tier {tiers[dead].name!r} has uplink_bps="
        f"{tiers[dead].uplink_bps!r}, availability="
        f"{tiers[dead].availability!r})"
        if dead is not None
        else "check the t_c/alpha/gamma profile for infs or NaNs"
    )
    return ValueError(f"no finite-cost multi-tier plan: {detail}")


#: Above this many candidate cut vectors the bucketed/overlap solve falls
#: back to the (approximate) lattice DP instead of exact enumeration.
_BUCKETED_ENUM_CAP = 50_000


def _tier_head_layers(
    branch_layers: Sequence[int], lo: int, hi: int, j: int, k: int, n: int
) -> list[int]:
    """Branch heads tier ``j`` (running layers ``(lo, hi]``) evaluates —
    the runtime's placement (``serving.tiers.segments_for_cuts``): strict
    at a cut (a branch there is discarded), none on the final tier of a
    K>=2 stack, and the deepest branch included at the trunk end of a
    single-tier plan."""
    if j == k - 1 and k > 1:
        return []
    return [b for b in branch_layers
            if lo < b and (b <= hi if hi == n else b < hi)]


def _solve_enumerated(
    t_c, alpha, p, tiers, batch, overlap, occupancy=None,
    head_cost=None, branch_layers=None,
) -> "MultiTierPlan | None":
    """Exact solve by enumeration: argmin over monotone cut vectors of the
    closed-form fixed-cut cost (entry-frozen bucketed and/or pipelined).
    Returns None when the enumeration would exceed ``_BUCKETED_ENUM_CAP``
    (caller falls back to the DP)."""
    n = len(t_c) - 1
    k = len(tiers)
    if k == 1:
        cost = expected_time_multitier(
            t_c, alpha, p, tiers, (), batch=batch, overlap=overlap,
            occupancy=occupancy, head_cost=head_cost,
            branch_layers=branch_layers,
        )
        return MultiTierPlan((), cost, tuple([0] * n))
    if math.comb(n + k - 1, k - 1) > _BUCKETED_ENUM_CAP:
        return None
    best_cost, best_cuts = np.inf, None
    for cuts in itertools.combinations_with_replacement(range(n + 1), k - 1):
        c = expected_time_multitier(
            t_c, alpha, p, tiers, cuts, batch=batch, overlap=overlap,
            occupancy=occupancy, head_cost=head_cost,
            branch_layers=branch_layers,
        )
        if c < best_cost:
            best_cost, best_cuts = c, cuts
    if best_cuts is None:
        raise _infeasible_error(tiers)
    bounds = (0, *best_cuts, n)
    tier_of_layer: list[int] = []
    for j in range(k):
        tier_of_layer += [j] * (bounds[j + 1] - bounds[j])
    return MultiTierPlan(tuple(best_cuts), float(best_cost), tuple(tier_of_layer))


def solve_multitier(
    t_c: np.ndarray,  # (N+1,) cloud-reference per-layer times, [0] == 0
    alpha: np.ndarray,  # (N+1,) output bytes, [0] == raw input
    branch_probs: np.ndarray,  # (N+1,) conditional exit prob per layer
    tiers: list[TierSpec],
    batch: int | None = None,
    *,
    overlap: bool = False,
    occupancy: float | None = None,
    head_cost: Callable[[int], float] | None = None,
    branch_layers: Sequence[int] | None = None,
) -> MultiTierPlan:
    """``batch=None`` is the paper's ideal per-sample model: every layer's
    cost is weighted by the probability the sample still runs it.

    ``batch`` given models the *survivor-compacted batched runtime*: the
    entry tier — the first tier that runs any layer, wherever it sits —
    computes the full batch (exits inside a tier are masked, not skipped),
    and each downstream tier computes a survivor sub-batch padded to the
    bucket ladder, frozen at tier entry.  Because "which tier is entry"
    and "what bucket a tier froze" are properties of the whole cut vector,
    not of a (layer, tier) lattice state, the bucketed solve enumerates
    cut vectors directly against :func:`expected_time_multitier` — exact
    by construction, and K (fleet depth) keeps the combinatorics tiny.
    Only above ``_BUCKETED_ENUM_CAP`` candidate vectors does it fall back
    to the lattice DP with *pointwise* padded stay weights (full batch on
    tier 0), a documented approximation.  Hop transfer is always
    reach-weighted: the wire ships true survivors, padding is a
    compute-shape artifact.

    ``overlap=True`` optimizes the pipelined runtime's steady-state step
    cost (the bottleneck stage ``max_j(compute_j, transfer_j)``) instead of
    the serial sum — see the module docstring.  Like the bucketed case it
    enumerates cut vectors; above the cap the serial DP's cuts are kept and
    re-scored under overlap (a documented approximation).

    ``occupancy`` (continuous batching; requires ``batch``) scales the
    expected live width: only that fraction of the nominal batch holds a
    live request in steady state, so downstream survivor sub-batches and
    hop payloads shrink by it.  The entry tier still computes the full
    nominal batch (dead slots are masked, not skipped — exactly the
    runtime's behavior), which is what moves the optimal cut toward the
    entry tier as occupancy drops.

    ``head_cost`` (with ``branch_layers``) adds the branch-head compute
    term: a callable ``m -> cloud-reference seconds`` for evaluating ``m``
    exit heads in one step (:func:`repro.core.profiler.branch_head_cost`
    builds it, batched or sequential).  The batched price couples a tier's
    heads into one stacked projection, which is not edge-decomposable over
    the lattice — so a ``head_cost`` solve always enumerates cut vectors
    (exact), falling back above ``_BUCKETED_ENUM_CAP`` to the head-less
    DP's cuts re-scored with the head term.  Without it the solver prices
    branch-heavy cuts as if heads were free — or, historically, callers
    padded ``t_c`` with K full per-head passes, over-pricing exactly the
    cuts the batched runtime makes cheap.
    """
    t_c = np.asarray(t_c, float)
    alpha = np.asarray(alpha, float)
    p = np.asarray(branch_probs, float)
    n = len(t_c) - 1
    k = len(tiers)
    assert k >= 1
    if occupancy is not None and batch is None:
        raise ValueError("occupancy models the batched runtime; pass batch=")

    if batch is not None or overlap or head_cost is not None:
        plan = _solve_enumerated(
            t_c, alpha, p, tiers, batch, overlap, occupancy,
            head_cost, branch_layers,
        )
        if plan is not None:
            return plan
    if overlap or head_cost is not None:
        # Enumeration overflowed the cap: take the serial DP's plan and
        # re-score it under the full cost.  (The batched head price
        # couples every branch a tier keeps into one stacked projection,
        # so — like the overlap bottleneck — it is not edge-decomposable
        # over the lattice; the DP solves without it, a documented
        # approximation above the cap.)
        plan = solve_multitier(t_c, alpha, p, tiers, batch)
        return dataclasses.replace(
            plan,
            expected_time_s=expected_time_multitier(
                t_c, alpha, p, tiers, plan.cut_after, batch=batch,
                overlap=overlap, occupancy=occupancy,
                head_cost=head_cost, branch_layers=branch_layers,
            ),
        )

    surv = np.cumprod(1.0 - p)  # surv[i] = alive after layer i's branch
    reach = np.concatenate([[1.0], surv[:-1]])  # alive entering layer i
    occ = 1.0 if occupancy is None else float(occupancy)

    def stay_w(i: int, j: int) -> float:
        if batch is None:
            return reach[i]
        return 1.0 if j == 0 else _padded_frac(reach[i] * occ, batch)

    # Branch semantics (paper Sec. IV-B): side branches run on every tier
    # EXCEPT the last (the cloud evaluates none), and the branch sitting
    # exactly at a cut is discarded (Fig. 2(c)).  So on tiers 0..K-2 the
    # survival bookkeeping is the global reach[] array, and the last tier's
    # whole tail is frozen at the survival of the final hop.  (For K >= 3
    # this treats a branch at an *intermediate* hop as evaluated by the
    # next branchy tier — exact whenever no branch sits exactly at a cut.)
    last = k - 1
    # dist[i][j]: layers 1..i done on branchy tiers, currently on tier j<last.
    dist = np.full((n + 1, max(last, 1)), np.inf)
    parent = np.full((n + 1, max(last, 1), 2), -1, dtype=int)
    dist[0][0] = 0.0
    for j in range(1, last):
        cand = dist[0][j - 1] + _hop_seconds(
            occ * alpha[0] * 8.0, tiers[j - 1].uplink_bps,
            tiers[j - 1].availability,
        )
        if cand < dist[0][j]:
            dist[0][j] = cand
            parent[0][j] = (0, j - 1)
    for i in range(1, n + 1):
        for j in range(last):
            cand = dist[i - 1][j] + stay_w(i, j) * _tier_layer_seconds(
                tiers[j], t_c[i], alpha[i]
            )
            if cand < dist[i][j]:
                dist[i][j] = cand
                parent[i][j] = (i - 1, j)
        for j in range(1, last):
            cand = dist[i][j - 1] + _hop_seconds(
                occ * reach[i] * alpha[i] * 8.0, tiers[j - 1].uplink_bps,
                tiers[j - 1].availability,
            )
            if cand < dist[i][j]:
                dist[i][j] = cand
                parent[i][j] = (i, j - 1)

    # Closed-form frozen tail on the last tier (no branches there); per-
    # layer seconds include the last tier's shard-width/collective terms.
    eff_last = np.array(
        [0.0]
        + [_tier_layer_seconds(tiers[last], t_c[i], alpha[i])
           for i in range(1, n + 1)]
    )
    tail = np.concatenate([np.cumsum(eff_last[::-1])[::-1][1:], [0.0]])
    best_cost, best_i, end_on_last = np.inf, n, False
    best_j_final: int | None = None
    if last >= 1:
        for j in range(last):
            if dist[n][j] < best_cost:  # finish without reaching the cloud
                best_cost, best_i, end_on_last = float(dist[n][j]), n, False
                best_j_final = j
        for i in range(0, n + 1):
            tail_w = (
                reach[i] if batch is None
                else _padded_frac(reach[i] * occ, batch)
            )
            hop = dist[i][last - 1] + (
                _hop_seconds(
                    occ * reach[i] * alpha[i] * 8.0,
                    tiers[last - 1].uplink_bps,
                    tiers[last - 1].availability,
                )
                + tail_w * tail[i]
            )
            if hop < best_cost:
                best_cost, best_i, end_on_last = float(hop), i, True
                best_j_final = last - 1
    else:  # single tier: everything runs there (full batch when bucketed)
        w1 = reach[1:] if batch is None else np.ones(n)
        eff0 = np.array(
            [_tier_layer_seconds(tiers[0], t_c[i], alpha[i])
             for i in range(1, n + 1)]
        )
        best_cost = float(np.sum(w1 * eff0))
        best_i, end_on_last, best_j_final = n, False, 0

    if best_j_final is None or not np.isfinite(best_cost):
        # Degenerate profile: no candidate assignment has finite cost (a
        # clear diagnostic instead of the historical UnboundLocalError).
        raise _infeasible_error(tiers)

    # Backtrack the branchy-tier assignment up to best_i.
    tier_of_layer = [last] * (n + 1)
    i, j = best_i, best_j_final
    while i > 0 or j > 0:
        pi, pj = parent[i][j]
        if pi < 0:
            break
        if pi == i - 1 and pj == j:
            tier_of_layer[i] = j
        i, j = int(pi), int(pj)
    cuts = []
    for j in range(1, k):
        after = max([i for i in range(1, n + 1) if tier_of_layer[i] < j],
                    default=0)
        cuts.append(after)
    return MultiTierPlan(
        cut_after=tuple(cuts),
        expected_time_s=best_cost,
        tier_of_layer=tuple(tier_of_layer[1:]),
    )


def expected_time_multitier(
    t_c: np.ndarray,
    alpha: np.ndarray,
    branch_probs: np.ndarray,
    tiers: list[TierSpec],
    cuts: tuple[int, ...],
    batch: int | None = None,
    *,
    overlap: bool = False,
    occupancy: float | None = None,
    head_cost: Callable[[int], float] | None = None,
    branch_layers: Sequence[int] | None = None,
) -> float:
    """Closed-form E[T] of one *fixed* monotone cut vector (the plan the
    runtime executes), same semantics as :func:`solve_multitier`: branches
    run on tiers 0..K-2 (reach-weighted), the last tier's tail is frozen at
    the wire survival, and a hop is charged iff layers still run after it.

    ``batch`` given switches to the survivor-compacted runtime's cost: the
    entry tier computes the full batch, and every later tier computes the
    bucket its entering survivors were padded to — *frozen at tier entry*
    (the runtime recompacts only at hops), so this is exact for the
    executed plan, padding waste included.  Transfers stay reach-weighted.

    ``overlap=True`` returns the pipelined runtime's steady-state step
    cost: the bottleneck stage ``max_j(compute_j, transfer_j)`` over the
    2K-1 pipeline stages (K tier computes interleaved with K-1 hop
    transfers) instead of their serial sum.  Per-stage weights are
    unchanged.  This models the real multi-host deployment where tiers
    compute concurrently; the single-host simulator serializes tier
    computes, so it matches this cost only when transfers dominate (see
    the ``serving.tiers`` module docstring).

    ``occupancy`` (requires ``batch``): the continuous-batching scheduler
    keeps only this fraction of the nominal batch live in steady state.
    The entry tier still computes the full nominal batch (dead slots are
    masked in place, exactly like intra-tier exits), while downstream
    survivor sub-batches — and every hop's payload — scale with the
    *live* width ``occupancy * batch`` before bucket padding.  This is
    the occupancy-weighted expected-batch term ``est_latency_s`` and the
    :class:`~repro.serving.controller.RepartitionController` price.

    ``head_cost`` (``m -> cloud-reference seconds`` for one step's ``m``
    exit heads; see :func:`repro.core.profiler.branch_head_cost`) adds a
    branch-head compute term per tier.  ``branch_layers`` names the branch
    positions (default: layers with nonzero ``branch_probs``); each tier's
    evaluated heads follow the runtime's placement (strict at a cut, none
    on the final tier of a K>=2 stack).  The tier's ``m`` heads are priced
    as ONE joint evaluation — ``head_cost(m)`` scaled by the tier's
    ``gamma / devices`` — weighted like its layer compute (bucketed
    sub-batch fraction; under ``batch=None`` each head is charged its
    reach times the amortized per-head share ``head_cost(m) / m``, which
    for a sequential-price callable degenerates to exactly the historical
    per-head charge).
    """
    t_c = np.asarray(t_c, float)
    alpha = np.asarray(alpha, float)
    p = np.asarray(branch_probs, float)
    n = len(t_c) - 1
    k = len(tiers)
    if len(cuts) != k - 1:
        raise ValueError(f"need {k - 1} cuts for {k} tiers, got {cuts}")
    bounds = (0, *(int(c) for c in cuts), n)
    if any(b > a for a, b in zip(bounds[1:], bounds[:-1])):
        raise ValueError(f"cuts must be non-decreasing in [0, {n}]: {cuts}")
    if occupancy is not None:
        if batch is None:
            raise ValueError(
                "occupancy models the batched runtime; pass batch="
            )
        if not 0.0 < occupancy <= 1.0:
            raise ValueError(f"occupancy must be in (0, 1]: {occupancy}")

    surv = np.cumprod(1.0 - p)
    reach = np.concatenate([[1.0], surv[:-1]])
    occ = 1.0 if occupancy is None else float(occupancy)
    entry = next((j for j in range(k) if bounds[j] < bounds[j + 1]), None)
    compute = [0.0] * k  # per-tier compute stage
    xfer = [0.0] * max(k - 1, 0)  # per-hop transfer stage
    for j in range(k):
        lo, hi = bounds[j], bounds[j + 1]
        for i in range(lo + 1, hi + 1):
            if batch is None:
                w = reach[bounds[k - 1]] if (j == k - 1 and k > 1) else reach[i]
            else:
                w = 1.0 if j == entry else _padded_frac(reach[lo] * occ, batch)
            compute[j] += w * _tier_layer_seconds(tiers[j], t_c[i], alpha[i])
    if head_cost is not None:
        blayers = (
            tuple(int(b) for b in branch_layers)
            if branch_layers is not None
            else tuple(i for i in range(1, n + 1) if p[i] > 0.0)
        )
        for j in range(k):
            lo, hi = bounds[j], bounds[j + 1]
            heads = _tier_head_layers(blayers, lo, hi, j, k, n)
            m = len(heads)
            if not m:
                continue
            scale = tiers[j].gamma / max(int(tiers[j].devices), 1)
            if batch is None:
                # Reach-weighted expected work: the joint evaluation's
                # amortized per-head share, charged at each head's reach.
                unit = head_cost(m) / m
                compute[j] += scale * sum(reach[i] * unit for i in heads)
            else:
                w = 1.0 if j == entry else _padded_frac(reach[lo] * occ, batch)
                compute[j] += scale * w * head_cost(m)
    for j in range(k - 1):
        c = bounds[j + 1]
        if c < n:  # layers still run downstream -> the hop really happens
            xfer[j] = _hop_seconds(
                occ * reach[c] * alpha[c] * 8.0, tiers[j].uplink_bps,
                tiers[j].availability,
            )
    if overlap:
        return float(max(compute + xfer))
    return float(sum(compute) + sum(xfer))


def from_cost_profile(profile: CostProfile, tiers: list[TierSpec]) -> MultiTierPlan:
    return solve_multitier(
        profile.t_c, profile.alpha, profile.branch_exit_probs(), tiers
    )
