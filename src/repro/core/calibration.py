"""Exit-probability calibration (paper Sec. III + Fig. 6).

BranchyNet exits when the classification *confidence* at a side branch
clears a threshold.  The paper uses entropy of the branch's probability
vector as the uncertainty metric; we normalize it to [0, 1] (divide by
log #classes) so one threshold works across vocab sizes.

The calibrator turns measured branch logits (from a validation batch) into
the conditional exit probabilities ``p_k`` the partitioner consumes — the
sequential structure matters: ``p_k`` is conditioned on *not* exiting at any
earlier branch (paper Eq. 4 then recovers the unconditional ``p_Y(k)``).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "normalized_entropy",
    "exit_mask",
    "CalibrationResult",
    "calibrate_exit_probs",
    "threshold_sweep",
]


def normalized_entropy(logits: jax.Array, axis: int = -1) -> jax.Array:
    """H(softmax(logits)) / log(C) in [0, 1]; numerically stable.

    Math runs in fp32 regardless of the logits dtype: the serving exit
    threshold compares this value, and the fused Pallas exit kernel
    (kernels/entropy_exit.py) accumulates in fp32 — a bf16 softmax here
    would make the two paths disagree at the threshold knife edge.  The
    log base is the logits *width* C (pad lanes included), matching the
    kernel and ``kernels.ref.entropy_exit_ref``.
    """
    lf = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(lf, axis=axis)
    h = -jnp.sum(jnp.exp(logp) * logp, axis=axis)
    c = logits.shape[axis]
    return h / jnp.log(c).astype(jnp.float32)


def exit_mask(logits: jax.Array, threshold: float) -> jax.Array:
    """True where the sample exits: normalized entropy below threshold."""
    return normalized_entropy(logits) < threshold


@dataclasses.dataclass(frozen=True)
class CalibrationResult:
    """Conditional exit probabilities and supporting statistics."""

    conditional_p: np.ndarray  # (K,) p_k given reached b_k
    unconditional_p: np.ndarray  # (K,) p_Y(k), Eq. 4
    exit_fraction: np.ndarray  # (K+1,) fraction exiting at each branch (+tail)
    threshold: float

    @property
    def survival(self) -> np.ndarray:
        return np.cumprod(1.0 - self.conditional_p)


def calibrate_exit_probs(
    branch_entropies: np.ndarray, threshold: float
) -> CalibrationResult:
    """From per-branch normalized entropies of a validation batch.

    ``branch_entropies``: (K, B) — entropy each of B samples would see at
    each of K branches (branches ordered along the chain).  The sequential
    exit process is simulated exactly: a sample contributes to branch k's
    statistics only if it cleared no earlier branch.
    """
    ents = np.asarray(branch_entropies, dtype=np.float64)
    if ents.ndim != 2:
        raise ValueError("branch_entropies must be (K, B)")
    k, b = ents.shape
    alive = np.ones(b, dtype=bool)
    cond, uncond, frac = [], [], []
    for i in range(k):
        exits = alive & (ents[i] < threshold)
        n_alive = int(alive.sum())
        p_cond = float(exits.sum() / n_alive) if n_alive else 0.0
        cond.append(p_cond)
        uncond.append(float(exits.sum() / b))
        frac.append(float(exits.sum() / b))
        alive &= ~exits
    frac.append(float(alive.sum() / b))  # classified at the output layer
    res = CalibrationResult(
        conditional_p=np.asarray(cond),
        unconditional_p=np.asarray(uncond),
        exit_fraction=np.asarray(frac),
        threshold=threshold,
    )
    # Internal consistency with Eq. 4: p_Y(k) = p_k prod_{i<k}(1 - p_i).
    alive_p = 1.0
    for i in range(k):
        expected = res.conditional_p[i] * alive_p
        assert abs(expected - res.unconditional_p[i]) < 1e-9
        alive_p *= 1.0 - res.conditional_p[i]
    return res


def threshold_sweep(
    branch_entropies: np.ndarray, thresholds: np.ndarray
) -> np.ndarray:
    """Fig. 6: P[classified at side branch] per threshold.

    Returns (T, K) unconditional exit probabilities.  Distortion enters via
    the entropies themselves (blurrier input -> flatter branch posterior ->
    higher entropy -> lower exit probability), reproducing the figure's
    monotone ordering across distortion levels.
    """
    out = np.stack(
        [
            calibrate_exit_probs(branch_entropies, float(t)).unconditional_p
            for t in np.asarray(thresholds)
        ]
    )
    return out
