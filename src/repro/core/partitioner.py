"""End-to-end partitioner: cost profile -> G'_BDNN -> shortest path -> plan.

This is the control plane a deployment calls at admission time (and again
whenever the network profile or the calibrated exit probabilities drift).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.calibration import CalibrationResult
from repro.core.latency import expected_time_all_splits
from repro.core.profiler import LayerCost
from repro.core.shortest_path import brute_force_split, shortest_path_plan
from repro.core.types import (
    UPLINK_PRESETS,
    BranchSpec,
    CostProfile,
    NetworkProfile,
    PartitionPlan,
)

__all__ = ["Partitioner", "build_cost_profile"]


def build_cost_profile(
    layer_costs: Sequence[LayerCost],
    branch_positions: Sequence[int],
    exit_probs: Sequence[float] | CalibrationResult,
    network: NetworkProfile | str,
    gamma: float,
    raw_input_bytes: float,
    branch_costs: Sequence[LayerCost] | None = None,
    include_branch_compute: bool = False,
) -> CostProfile:
    """Assemble a CostProfile from profiler output + calibration.

    ``layer_costs`` covers the N main-branch layers in chain order;
    ``branch_positions[j]`` is the 1-based main layer feeding branch j.
    """
    if isinstance(network, str):
        network = UPLINK_PRESETS[network]
    if isinstance(exit_probs, CalibrationResult):
        exit_probs = exit_probs.conditional_p
    if len(branch_positions) != len(exit_probs):
        raise ValueError("one exit probability per branch position")
    t_c = np.concatenate([[0.0], [c.time_s for c in layer_costs]])
    alpha = np.concatenate([[raw_input_bytes], [c.output_bytes for c in layer_costs]])
    names = ("input", *(c.name for c in layer_costs))
    branches = []
    for j, (pos, p) in enumerate(zip(branch_positions, exit_probs)):
        bc = branch_costs[j].time_s if branch_costs is not None else 0.0
        branches.append(BranchSpec(after_layer=int(pos), exit_prob=float(p), compute_time_cloud=bc))
    return CostProfile(
        t_c=t_c,
        alpha=alpha,
        branches=tuple(branches),
        gamma=gamma,
        network=network,
        include_branch_compute=include_branch_compute,
        layer_names=names,
    )


@dataclasses.dataclass
class Partitioner:
    """Solves the BranchyNet partitioning problem for one cost profile.

    ``method``: "dijkstra" (the paper's solver, run on the explicit graph)
    or "brute_force" (closed-form argmin oracle).  They always agree; the
    graph solver is kept as the deployed path because it extends to DAGs
    (repro.core.dag) where no closed form exists.
    """

    profile: CostProfile
    method: str = "dijkstra"

    def solve(self) -> PartitionPlan:
        if self.method == "dijkstra":
            return shortest_path_plan(self.profile)
        if self.method == "brute_force":
            return brute_force_split(self.profile)
        raise ValueError(f"unknown method {self.method!r}")

    def all_split_times(self) -> np.ndarray:
        return expected_time_all_splits(self.profile)

    def with_network(self, network: NetworkProfile | str) -> "Partitioner":
        if isinstance(network, str):
            network = UPLINK_PRESETS[network]
        return Partitioner(dataclasses.replace(self.profile, network=network), self.method)

    def with_gamma(self, gamma: float) -> "Partitioner":
        return Partitioner(dataclasses.replace(self.profile, gamma=gamma), self.method)

    def with_exit_probs(self, probs: Sequence[float]) -> "Partitioner":
        branches = tuple(
            dataclasses.replace(b, exit_prob=float(p))
            for b, p in zip(self.profile.branches, probs)
        )
        return Partitioner(
            dataclasses.replace(self.profile, branches=branches), self.method
        )
