"""Per-layer cost extraction: the partitioner's ``t_c`` / ``alpha`` inputs.

The paper measures ``t_i^c`` on Google Colab (K80) and sets
``t_i^e = gamma * t_i^c``.  We support two sources:

  * :func:`measure_layer_times` — wall-clock each layer callable on the local
    CPU device (paper-faithful for the B-AlexNet reproduction);
  * :func:`analyze_layer_costs` — derive roofline times from the compiled
    HLO of each layer (``cost_analysis()``): t = max(flops/peak, bytes/bw).
    This is the deployable path — no hardware in the loop (DESIGN.md Sec. 7).

Both return a :class:`repro.core.types.CostProfile`-ready pair of arrays.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "HardwareSpec",
    "TPU_V5E",
    "LayerCost",
    "analyze_layer_costs",
    "measure_layer_times",
    "output_bytes",
]


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Roofline constants for one accelerator tier."""

    name: str
    peak_flops: float  # FLOP/s (bf16 unless noted)
    hbm_bw: float  # bytes/s
    link_bw: float = 50e9  # bytes/s per ICI link
    hbm_bytes: float = 16e9

    def roofline_time(self, flops: float, bytes_: float) -> float:
        """Execution time lower bound: max of compute and memory terms."""
        return max(flops / self.peak_flops, bytes_ / self.hbm_bw)


#: The target accelerator for this framework (system prompt constants).
TPU_V5E = HardwareSpec("tpu-v5e", peak_flops=197e12, hbm_bw=819e9)


@dataclasses.dataclass(frozen=True)
class LayerCost:
    name: str
    flops: float
    bytes_accessed: float
    output_bytes: float
    time_s: float


def output_bytes(tree) -> float:
    """Total bytes of a pytree of abstract/concrete arrays (the paper's
    alpha_i for the tensor that crosses the cut)."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0.0
    for leaf in leaves:
        total += float(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
    return total


def _cost_analysis(fn: Callable, *abstract_args) -> dict:
    lowered = jax.jit(fn).lower(*abstract_args)
    compiled = lowered.compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jax returns [dict]
        ca = ca[0]
    return ca or {}


def analyze_layer_costs(
    layer_fns: Sequence[tuple[str, Callable]],
    layer_inputs: Sequence,
    hardware: HardwareSpec = TPU_V5E,
) -> list[LayerCost]:
    """Roofline-cost every layer of a chain from its compiled HLO.

    ``layer_fns[i]`` maps layer i's input pytree to its output pytree;
    ``layer_inputs[i]`` is a pytree of ShapeDtypeStructs.  No device memory
    is allocated.
    """
    out: list[LayerCost] = []
    for (name, fn), args in zip(layer_fns, layer_inputs):
        ca = _cost_analysis(fn, args)
        flops = float(ca.get("flops", 0.0))
        bytes_accessed = float(ca.get("bytes accessed", 0.0))
        shape = jax.eval_shape(fn, args)
        ob = output_bytes(shape)
        t = hardware.roofline_time(flops, max(bytes_accessed, ob))
        out.append(LayerCost(name, flops, bytes_accessed, ob, t))
    return out


def measure_layer_times(
    layer_fns: Sequence[tuple[str, Callable]],
    layer_inputs: Sequence,
    iters: int = 10,
    warmup: int = 2,
) -> list[LayerCost]:
    """Wall-clock per-layer timing on the local device (paper Sec. VI mode).

    ``layer_inputs`` here are concrete arrays.  Used by the B-AlexNet
    reproduction where the paper measured Colab times; everything is jitted
    and block_until_ready'd so we time steady-state compute only.
    """
    out: list[LayerCost] = []
    for (name, fn), args in zip(layer_fns, layer_inputs):
        jf = jax.jit(fn)
        res = jf(args)
        jax.block_until_ready(res)
        for _ in range(warmup):
            jax.block_until_ready(jf(args))
        t0 = time.perf_counter()
        for _ in range(iters):
            res = jf(args)
        jax.block_until_ready(res)
        dt = (time.perf_counter() - t0) / iters
        ob = output_bytes(jax.eval_shape(fn, args))
        out.append(LayerCost(name, 0.0, 0.0, ob, dt))
    return out
