"""Per-layer cost extraction: the partitioner's ``t_c`` / ``alpha`` inputs.

The paper measures ``t_i^c`` on Google Colab (K80) and sets
``t_i^e = gamma * t_i^c``.  We support two sources:

  * :func:`measure_layer_times` — wall-clock each layer callable on the local
    CPU device (paper-faithful for the B-AlexNet reproduction);
  * :func:`analyze_layer_costs` — derive roofline times from the compiled
    HLO of each layer (``cost_analysis()``): t = max(flops/peak, bytes/bw).
    This is the deployable path — no hardware in the loop (DESIGN.md Sec. 7).

Both return a :class:`repro.core.types.CostProfile`-ready pair of arrays.

:func:`profile_decode_layers` builds the serving-relevant inputs for
either source directly from a BranchyNet trunk: one decode-step callable
per trunk layer (its residual update *including* the resident-cache
read/write), dispatched through the same ``use_kernels`` tri-state as the
tier runtime — so ``compute_j`` can come from the Pallas kernel lowering
(interpret mode off-TPU) instead of only the jnp path, and the cost model
prices what the runtime actually executes.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "HardwareSpec",
    "TPU_V5E",
    "LayerCost",
    "analyze_layer_costs",
    "branch_head_cost",
    "decode_layer_fns",
    "measure_layer_times",
    "output_bytes",
    "profile_decode_layers",
]


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Roofline constants for one accelerator tier."""

    name: str
    peak_flops: float  # FLOP/s (bf16 unless noted)
    hbm_bw: float  # bytes/s
    link_bw: float = 50e9  # bytes/s per ICI link
    hbm_bytes: float = 16e9

    def roofline_time(
        self, flops: float, bytes_: float, devices: int = 1
    ) -> float:
        """Execution time lower bound: max of compute and memory terms.

        ``devices > 1`` models a tensor-parallel shard of the layer: FLOPs
        and HBM traffic split across the shard width; the collective cost
        of re-assembling the activation is priced separately by
        :func:`collective_time` (the sum feeds ``TierSpec.devices``-aware
        profiles)."""
        d = max(int(devices), 1)
        return max(flops / d / self.peak_flops, bytes_ / d / self.hbm_bw)

    def collective_time(self, activation_bytes: float, devices: int) -> float:
        """Per-layer intra-tier collective term: a ring all-reduce of the
        layer's activation over the ICI link, twice per layer (attention-
        out + MLP-down partial sums) — the profiler-side mirror of
        ``repro.core.multitier._collective_seconds``."""
        d = max(int(devices), 1)
        if d <= 1 or activation_bytes <= 0.0:
            return 0.0
        return 2.0 * (2.0 * (d - 1) / d) * activation_bytes / self.link_bw


#: The target accelerator for this framework (system prompt constants).
TPU_V5E = HardwareSpec("tpu-v5e", peak_flops=197e12, hbm_bw=819e9)


@dataclasses.dataclass(frozen=True)
class LayerCost:
    name: str
    flops: float
    bytes_accessed: float
    output_bytes: float
    time_s: float


def output_bytes(tree) -> float:
    """Total bytes of a pytree of abstract/concrete arrays (the paper's
    alpha_i for the tensor that crosses the cut)."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0.0
    for leaf in leaves:
        total += float(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
    return total


def _cost_analysis(fn: Callable, *abstract_args) -> dict:
    lowered = jax.jit(fn).lower(*abstract_args)
    compiled = lowered.compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jax returns [dict]
        ca = ca[0]
    return ca or {}


def analyze_layer_costs(
    layer_fns: Sequence[tuple[str, Callable]],
    layer_inputs: Sequence,
    hardware: HardwareSpec = TPU_V5E,
    *,
    devices: int = 1,
) -> list[LayerCost]:
    """Roofline-cost every layer of a chain from its compiled HLO.

    ``layer_fns[i]`` maps layer i's input pytree to its output pytree;
    ``layer_inputs[i]`` is a pytree of ShapeDtypeStructs.  No device memory
    is allocated.

    ``devices > 1`` prices a mesh-sharded tier: each layer's roofline time
    divides by the shard width and gains the per-layer collective term
    (``HardwareSpec.collective_time`` on the layer's output activation) —
    the same two cost-model terms ``TierSpec(devices=, ici_bps=)`` carries
    into :func:`repro.core.multitier.solve_multitier`.
    """
    out: list[LayerCost] = []
    for (name, fn), args in zip(layer_fns, layer_inputs):
        ca = _cost_analysis(fn, args)
        flops = float(ca.get("flops", 0.0))
        bytes_accessed = float(ca.get("bytes accessed", 0.0))
        shape = jax.eval_shape(fn, args)
        ob = output_bytes(shape)
        t = hardware.roofline_time(flops, max(bytes_accessed, ob), devices)
        t += hardware.collective_time(ob, devices)
        out.append(LayerCost(name, flops, bytes_accessed, ob, t))
    return out


def measure_layer_times(
    layer_fns: Sequence[tuple[str, Callable]],
    layer_inputs: Sequence,
    iters: int = 10,
    warmup: int = 2,
) -> list[LayerCost]:
    """Wall-clock per-layer timing on the local device (paper Sec. VI mode).

    ``layer_inputs`` here are concrete arrays.  Used by the B-AlexNet
    reproduction where the paper measured Colab times; everything is jitted
    and block_until_ready'd so we time steady-state compute only.
    """
    out: list[LayerCost] = []
    for (name, fn), args in zip(layer_fns, layer_inputs):
        jf = jax.jit(fn)
        res = jf(args)
        jax.block_until_ready(res)
        for _ in range(warmup):
            jax.block_until_ready(jf(args))
        t0 = time.perf_counter()
        for _ in range(iters):
            res = jf(args)
        jax.block_until_ready(res)
        dt = (time.perf_counter() - t0) / iters
        ob = output_bytes(jax.eval_shape(fn, args))
        out.append(LayerCost(name, 0.0, 0.0, ob, dt))
    return out


# ------------------------------------------------- branch-head pricing
def branch_head_cost(
    cfg,
    batch: int,
    *,
    heads_batched: bool = True,
    hardware: HardwareSpec = TPU_V5E,
):
    """Roofline seconds to evaluate ``m`` tied exit heads in one decode
    step at the cloud-reference tier: per-branch norm + the shared
    (D, V) unembedding applied to a (batch, D) hidden per head.

    Returns a callable ``m -> seconds`` (``m = 0`` is free) — the
    ``head_cost=`` input of :func:`repro.core.multitier.solve_multitier` /
    ``expected_time_multitier`` and both servers' ``est_latency_s``.

    ``heads_batched=True`` prices the runtime's stacked evaluation
    (``TierExecutor(batched_heads=True)``, the default): FLOPs still scale
    with ``m``, but the dominant HBM term — streaming (and casting) the
    D x V unembedding weight — is paid ONCE for the whole stack, so ``m``
    heads cost about one head's bandwidth.  ``heads_batched=False`` prices
    the sequential per-head lowering: ``m`` independent projections, each
    re-reading the weight — what probe-step estimates used to charge
    unconditionally (K full head passes) even when the runtime batches.
    """
    d = float(cfg.d_model)
    v = float(cfg.padded_vocab_size)
    b = float(batch)
    itemsize = 2.0 if cfg.dtype == "bfloat16" else 4.0
    w_bytes = d * v * itemsize  # the shared unembedding read
    act_bytes = b * (d + v) * itemsize  # per-head hidden read + logits write
    flops_per_head = 2.0 * b * d * v

    def cost(m: int) -> float:
        m = int(m)
        if m <= 0:
            return 0.0
        if heads_batched:
            return hardware.roofline_time(
                m * flops_per_head, w_bytes + m * act_bytes
            )
        return m * hardware.roofline_time(flops_per_head, w_bytes + act_bytes)

    return cost


# ------------------------------------------------- serving decode profiles
def decode_layer_fns(
    cfg,
    params,
    batch: int,
    context_len: int,
    *,
    use_kernels: bool | None = None,
    pos: int | None = None,
) -> tuple[list[tuple[str, Callable]], list]:
    """Per-trunk-layer decode-step callables + their input pytrees.

    Layer ``i``'s callable maps ``(h (B, 1, d), caches)`` to the residual
    stream after layer ``i`` — including the layer's resident-cache
    read/write — through :func:`repro.models.model.run_trunk` with the
    SAME ``use_kernels`` dispatch the tier runtime uses (None = the
    config's tri-state: auto on TPU; True off-TPU runs the Pallas kernels
    in interpret mode).  Feed the pairs to :func:`analyze_layer_costs`
    (inputs become ShapeDtypeStructs automatically) or
    :func:`measure_layer_times` via :func:`profile_decode_layers`.

    ``output_bytes`` of each callable is the residual stream — the
    paper's per-layer ``alpha_i`` — because the cache stays resident and
    never crosses a cut.
    """
    # Deferred: core.profiler is imported by repro.core.__init__, and the
    # model stack imports repro.core submodules.
    from repro.kernels.ops import resolve_use_kernels
    from repro.models import model as M

    kernels = resolve_use_kernels(
        cfg.use_kernels if use_kernels is None else use_kernels
    )
    total = sum(n for _, _, n in M.trunk_layout(cfg))
    dtype = M.compute_dtype(cfg)
    # Mid-context query position: the cache is charged at its full
    # resident size either way (static shapes), the position only gates
    # the validity mask.
    positions = jnp.full((1,), pos if pos is not None else context_len // 2,
                         jnp.int32)

    def make_fn(i: int) -> Callable:
        def fn(args):
            h, caches = args
            h2, _, _, _ = M.run_trunk(
                params, h, cfg, positions, caches,
                layer_range=(i, i + 1), use_kernels=kernels,
            )
            return h2

        return fn

    fns = [(f"layer{i + 1}", make_fn(i)) for i in range(total)]
    h0 = jnp.zeros((batch, 1, cfg.d_model), dtype)
    caches = M.init_caches(cfg, batch, context_len)
    inputs = [(h0, caches)] * total
    return fns, inputs


def profile_decode_layers(
    cfg,
    params,
    batch: int,
    context_len: int,
    *,
    use_kernels: bool | None = None,
    mode: str = "analyze",
    hardware: HardwareSpec = TPU_V5E,
    iters: int = 10,
    warmup: int = 2,
    devices: int = 1,
) -> list[LayerCost]:
    """Per-layer decode-step costs of a BranchyNet trunk, kernel-aware.

    ``mode="analyze"`` rooflines each layer's compiled HLO (no device
    work beyond compilation); ``mode="measure"`` wall-clocks it.  Either
    way the lowered program is the tier runtime's own decode math —
    ``use_kernels=True`` prices the Pallas kernel lowering, ``False`` the
    jnp lowering, ``None`` the config/backend default — so the resulting
    ``t_c`` feeds :class:`~repro.core.types.CostProfile` with
    runtime-faithful ``compute_j`` terms.

    ``devices`` (analyze mode) prices the layers as a mesh-sharded tier
    would run them: roofline over the shard width plus the per-layer
    collective term — sharded segments resolve ``use_kernels`` to the jnp
    path, matching the runtime's sharded dispatch."""
    if mode not in ("analyze", "measure"):
        raise ValueError(f"unknown profiling mode: {mode!r}")
    from repro.kernels.ops import resolve_use_kernels

    if devices > 1:
        use_kernels = resolve_use_kernels(use_kernels, sharded=True)
    fns, inputs = decode_layer_fns(
        cfg, params, batch, context_len, use_kernels=use_kernels
    )
    if mode == "analyze":
        abstract = [
            jax.tree_util.tree_map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), args
            )
            for args in inputs
        ]
        return analyze_layer_costs(fns, abstract, hardware, devices=devices)
    return measure_layer_times(fns, inputs, iters=iters, warmup=warmup)
