"""Expected-inference-time model for a partitioned BranchyNet.

Implements paper Eqs. 1-6 and their natural generalization to many branches.

Semantics (Sec. IV-B/IV-C of the paper):

  * the edge processes ``v_1 .. v_s`` and evaluates the side branches
    ``b_k`` with ``after_layer < s`` (the branch sitting exactly at the cut,
    ``after_layer == s``, is *not* evaluated — Fig. 2(c) ships ``alpha_s``
    immediately);
  * the cloud never evaluates side branches (Sec. IV-B);
  * every cost incurred strictly after branch ``b_k`` is weighted by the
    survival probability ``prod_{j <= k} (1 - p_j)`` — in the paper's
    single-branch case this is exactly the ``(1 - p_Y(k))`` factor of Eq. 5.

Eq. 8 in the paper writes the multiplier as ``p_Y(k)``; read literally that
*up*-weights late links when exits are likely, contradicting both Eq. 5 and
the quoted text ("the higher the probability ... the less significant are the
weights of links after the side branch").  We therefore implement the
survival-probability reading, which reproduces Eq. 5 exactly.  Recorded in
EXPERIMENTS.md (Paper-validation).
"""

from __future__ import annotations

import numpy as np

from repro.core.types import CostProfile, PartitionPlan

__all__ = [
    "expected_time",
    "expected_time_all_splits",
    "plan_from_split",
]


def _edge_layer_weights(profile: CostProfile, include_branches: bool) -> np.ndarray:
    """Per-main-layer expected *edge* cost, reach-probability weighted.

    Returns ``w`` of shape (N+1,) where ``w[i]`` is the expected time the edge
    spends on layer ``v_i`` (plus its branch head, if modeled) given that the
    partition lies at or beyond ``i``.  ``w[0] == 0``.
    """
    t_e = profile.t_e
    surv = profile.survival_after()  # surv[i] = P[alive after v_i's branch]
    n = profile.num_layers
    w = np.zeros(n + 1)
    # reach(v_i) = survival after branch b_{i-1} = surv[i-1].
    w[1:] = t_e[1:] * surv[:-1]
    if include_branches:
        for b in profile.branches:
            # Branch b_k runs right after v_k, reached with prob surv[k-1].
            # It is evaluated only when the cut lies strictly beyond v_k
            # (Fig. 2(c)), so its cost belongs to splits s >= k+1 -> slot k+1.
            w[b.after_layer + 1] += (
                profile.gamma * b.compute_time_cloud * surv[b.after_layer - 1]
            )
    return w


def expected_time_all_splits(profile: CostProfile) -> np.ndarray:
    """E[T_inf(s)] for every split ``s in 0..N`` as a closed-form vector.

    ``s == 0`` is cloud-only (upload raw input, Eq. 3 with T_e = 0);
    ``s == N`` is edge-only (no transfer).  This is the chain-DAG shortest
    path evaluated exhaustively -- used as the oracle and by the vectorized
    sensitivity sweeps.
    """
    n = profile.num_layers
    t_c = profile.t_c
    t_net = profile.t_net
    w_e = _edge_layer_weights(profile, profile.include_branch_compute)
    surv = profile.survival_after()

    cum_edge = np.cumsum(w_e)  # cum_edge[s] = expected edge time through v_s
    # tail_cloud[s] = sum_{i>s} t_i^c  (cloud evaluates no branches).
    tail_cloud = np.concatenate([np.cumsum(t_c[::-1])[::-1][1:], [0.0]])

    # Survival probability *entering the link* out of v_s: branches evaluated
    # on the edge are those with after_layer <= s-1, i.e. surv at index s-1;
    # cloud-only (s=0) ships with probability 1.
    surv_at_cut = np.ones(n + 1)
    surv_at_cut[1:] = surv[:-1]

    cost = cum_edge + surv_at_cut * (t_net + tail_cloud)
    # Edge-only pays no transfer.
    cost[n] = cum_edge[n]
    return cost


def expected_time(profile: CostProfile, split_layer: int) -> float:
    """E[T_inf] (paper Eq. 5/6) for one split point."""
    n = profile.num_layers
    if not 0 <= split_layer <= n:
        raise ValueError(f"split_layer must be in 0..{n}")
    return float(expected_time_all_splits(profile)[split_layer])


def plan_from_split(
    profile: CostProfile, split_layer: int, method: str = "closed_form"
) -> PartitionPlan:
    n = profile.num_layers
    t = expected_time(profile, split_layer)
    edge_layers = tuple(range(1, split_layer + 1))
    cloud_layers = tuple(range(split_layer + 1, n + 1))
    edge_branches = tuple(
        b.after_layer for b in profile.branches if b.after_layer < split_layer
    )
    tx = float(profile.alpha[split_layer]) if split_layer < n else 0.0
    return PartitionPlan(
        split_layer=split_layer,
        expected_time_s=t,
        edge_layers=edge_layers,
        cloud_layers=cloud_layers,
        edge_branches=edge_branches,
        transfer_bytes=tx,
        method=method,
    )
