"""Core datatypes for BranchyNet partitioning (Pacheco & Couto, ISCC 2020).

The control plane works on a *cost profile* of a chain DNN:

  * ``N`` main-branch layers ``v_1 .. v_N`` (vertex ``v_0`` is the virtual
    *input*; index 0 in the arrays below is the raw input sample).
  * side branches ``b_k`` attached after main layers (``branch_after[j]`` is
    the 1-based index of the main layer whose output feeds branch ``j``).
  * per-layer cloud compute times ``t_c`` and output sizes ``alpha`` (bytes);
    edge times are ``t_e = gamma * t_c`` exactly as in the paper (Sec. VI).
  * per-branch conditional exit probabilities ``p`` (paper Sec. IV-C).

All arrays are plain numpy on the control plane; the vectorized solver
(:mod:`repro.core.shortest_path`) mirrors them in jnp.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = [
    "NetworkProfile",
    "UPLINK_PRESETS",
    "BranchSpec",
    "CostProfile",
    "PartitionPlan",
]


@dataclasses.dataclass(frozen=True)
class NetworkProfile:
    """A link between the edge tier and the cloud tier."""

    name: str
    bandwidth_bps: float  # bits per second (paper uses Mbps uplink rates)
    latency_s: float = 0.0  # fixed RTT component (0 in the paper)

    def transfer_time(self, nbytes: float | np.ndarray) -> np.ndarray:
        """t_net = alpha / B (paper Sec. IV-C), plus optional fixed latency."""
        return np.asarray(nbytes) * 8.0 / self.bandwidth_bps + self.latency_s


#: Average uplink rates used in the paper's evaluation (Sec. VI, from DADS).
UPLINK_PRESETS = {
    "3g": NetworkProfile("3g", 1.10e6),
    "4g": NetworkProfile("4g", 5.85e6),
    "wifi": NetworkProfile("wifi", 18.80e6),
    # TPU-fleet tiers (beyond-paper; DESIGN.md Sec. 2).
    "dcn": NetworkProfile("dcn", 12.5e9 * 8),  # ~12.5 GB/s per host, inter-pod
    "ici": NetworkProfile("ici", 50e9 * 8),  # ~50 GB/s per link, intra-pod
}


@dataclasses.dataclass(frozen=True)
class BranchSpec:
    """Side branch ``b_k`` placed after main-branch layer ``after_layer``."""

    after_layer: int  # 1-based index into the main branch
    exit_prob: float  # conditional p_k = P[exit at b_k | reached b_k]
    compute_time_cloud: float = 0.0  # t_{b_k}^c; the paper neglects this

    def __post_init__(self):
        if not (0.0 <= self.exit_prob <= 1.0):
            raise ValueError(f"exit_prob must be in [0,1], got {self.exit_prob}")
        if self.after_layer < 1:
            raise ValueError("branches attach after main layer >= 1")


@dataclasses.dataclass(frozen=True)
class CostProfile:
    """Everything the partitioner needs to know about one (model, HW, net).

    ``t_c[i]`` / ``alpha[i]`` are indexed by main-branch layer ``i`` in
    ``1..N`` with slot 0 describing the raw input: ``alpha[0]`` is the raw
    sample size (upload cost of cloud-only processing) and ``t_c[0] == 0``.
    """

    t_c: np.ndarray  # (N+1,) cloud per-layer time, [0] == 0
    alpha: np.ndarray  # (N+1,) output bytes per layer, [0] == raw input bytes
    branches: tuple[BranchSpec, ...]
    gamma: float  # t_e = gamma * t_c (paper Sec. VI)
    network: NetworkProfile
    # Paper-faithful mode ignores side-branch compute time (Eq. 5). Setting
    # this True adds t_b^{e} = gamma * compute_time_cloud at each edge branch.
    include_branch_compute: bool = False
    layer_names: tuple[str, ...] | None = None  # (N+1,), [0] == "input"

    def __post_init__(self):
        t_c = np.asarray(self.t_c, dtype=np.float64)
        alpha = np.asarray(self.alpha, dtype=np.float64)
        object.__setattr__(self, "t_c", t_c)
        object.__setattr__(self, "alpha", alpha)
        if t_c.shape != alpha.shape or t_c.ndim != 1:
            raise ValueError("t_c and alpha must be 1-D with equal length")
        if t_c[0] != 0.0:
            raise ValueError("t_c[0] is the virtual input layer and must be 0")
        if self.gamma < 1.0:
            raise ValueError("gamma >= 1 (edge is never faster than cloud)")
        n = self.num_layers
        seen = set()
        for b in self.branches:
            if b.after_layer >= n:  # a branch after v_N would be the output
                raise ValueError(f"branch after_layer {b.after_layer} >= N={n}")
            if b.after_layer in seen:
                raise ValueError("at most one branch per main layer")
            seen.add(b.after_layer)
        object.__setattr__(
            self, "branches", tuple(sorted(self.branches, key=lambda b: b.after_layer))
        )

    @property
    def num_layers(self) -> int:
        return int(self.t_c.shape[0]) - 1

    @property
    def t_e(self) -> np.ndarray:
        return self.t_c * self.gamma

    @property
    def t_net(self) -> np.ndarray:
        """t_i^net = alpha_i / B for every potential cut point (incl. input)."""
        return self.network.transfer_time(self.alpha)

    def branch_exit_probs(self) -> np.ndarray:
        """Per-main-layer conditional exit prob (0 where no branch)."""
        p = np.zeros(self.num_layers + 1)
        for b in self.branches:
            p[b.after_layer] = b.exit_prob
        return p

    def survival_after(self) -> np.ndarray:
        """``surv[i]`` = P[sample not yet exited after processing v_i and its
        branch] = prod_{b_k: after_layer <= i} (1 - p_k).  ``surv[0] == 1``."""
        p = self.branch_exit_probs()
        return np.cumprod(1.0 - p)

    def p_Y(self) -> np.ndarray:
        """Paper Eq. 4: unconditional exit prob per branch, aligned with
        ``self.branches`` ordering."""
        out = []
        alive = 1.0
        for b in self.branches:
            out.append(alive * b.exit_prob)
            alive *= 1.0 - b.exit_prob
        return np.asarray(out)


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    """Result of the optimization: process v_1..v_s on the edge, ship
    alpha_s bytes, process v_{s+1}..v_N in the cloud.  s == 0 is cloud-only,
    s == N is edge-only (paper Fig. 2)."""

    split_layer: int
    expected_time_s: float
    edge_layers: tuple[int, ...]
    cloud_layers: tuple[int, ...]
    edge_branches: tuple[int, ...]  # after_layer of branches evaluated on edge
    transfer_bytes: float
    method: str = "dijkstra"

    @property
    def is_cloud_only(self) -> bool:
        return self.split_layer == 0

    @property
    def is_edge_only(self) -> bool:
        return len(self.cloud_layers) == 0

    def describe(self, names: Sequence[str] | None = None) -> str:
        def nm(i: int) -> str:
            return names[i] if names else f"v{i}"

        if self.is_cloud_only:
            where = "cloud-only"
        elif self.is_edge_only:
            where = "edge-only"
        else:
            where = f"split after {nm(self.split_layer)}"
        return (
            f"PartitionPlan[{where}] E[T]={self.expected_time_s * 1e3:.3f} ms, "
            f"tx={self.transfer_bytes / 1024:.1f} KiB, "
            f"edge={len(self.edge_layers)}L+{len(self.edge_branches)}b, "
            f"cloud={len(self.cloud_layers)}L"
        )
