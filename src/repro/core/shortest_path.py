"""Shortest-path solvers for BranchyNet partitioning (paper Sec. V).

Three interchangeable solvers, cross-checked in tests:

  * :func:`dijkstra` — the paper's solver, run on the explicit ``G'_BDNN``
    graph.  O(m + n log n) with a binary heap; control-plane (pure Python).
  * :func:`brute_force_split` — evaluates Eq. 5/6 at every split; the oracle.
  * :func:`solve_chain_jax` — JAX closed form of the chain shortest path,
    jit/vmap-able over (bandwidth, gamma, p) grids; this is what the Fig. 4/5
    sensitivity sweeps use (a whole figure is one ``vmap``).  Beyond-paper:
    the paper runs Dijkstra once per parameter point.
"""

from __future__ import annotations

import heapq

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph, build_partition_graph, split_of_path
from repro.core.latency import expected_time_all_splits, plan_from_split
from repro.core.types import CostProfile, PartitionPlan

__all__ = [
    "dijkstra",
    "shortest_path_plan",
    "brute_force_split",
    "solve_chain_jax",
    "chain_costs_jax",
]


def dijkstra(
    graph: Graph, source: str = "input", target: str = "output"
) -> tuple[float, list[str]]:
    """Textbook Dijkstra with a lazy-deletion heap.  Returns (dist, path)."""
    if source not in graph.adj or target not in graph.adj:
        raise KeyError("source/target not in graph")
    dist: dict[str, float] = {source: 0.0}
    prev: dict[str, str] = {}
    done: set[str] = set()
    heap: list[tuple[float, str]] = [(0.0, source)]
    while heap:
        d, u = heapq.heappop(heap)
        if u in done:
            continue
        done.add(u)
        if u == target:
            break
        for v, w in graph.adj[u]:
            nd = d + w
            if nd < dist.get(v, float("inf")):
                dist[v] = nd
                prev[v] = u
                heapq.heappush(heap, (nd, v))
    if target not in dist:
        raise ValueError("target unreachable")
    path = [target]
    while path[-1] != source:
        path.append(prev[path[-1]])
    path.reverse()
    return dist[target], path


def shortest_path_plan(profile: CostProfile) -> PartitionPlan:
    """Paper's method end to end: build G'_BDNN, run Dijkstra, decode s."""
    g = build_partition_graph(profile)
    cost, path = dijkstra(g)
    s = split_of_path(path)
    plan = plan_from_split(profile, s, method="dijkstra")
    # The graph cost should equal the closed form up to the epsilon link.
    assert abs(cost - plan.expected_time_s) < 1e-6 + 1e-9 * abs(cost), (
        f"graph/closed-form divergence: {cost} vs {plan.expected_time_s}"
    )
    return plan


def brute_force_split(profile: CostProfile) -> PartitionPlan:
    """Oracle: argmin over all N+1 splits of the closed-form E[T]."""
    costs = expected_time_all_splits(profile)
    s = int(np.argmin(costs))
    return plan_from_split(profile, s, method="brute_force")


# ---------------------------------------------------------------------------
# JAX closed-form solver (vectorized sensitivity sweeps)
# ---------------------------------------------------------------------------


def chain_costs_jax(
    t_c: jax.Array,  # (N+1,)  cloud per-layer seconds, [0] == 0
    alpha: jax.Array,  # (N+1,)  output bytes per layer, [0] == raw input
    p: jax.Array,  # (N+1,)  conditional exit prob per layer (0 = no branch)
    gamma: jax.Array,  # scalar edge slowdown
    bandwidth_bps: jax.Array,  # scalar
    branch_t_c: jax.Array | None = None,  # (N+1,) branch head cloud seconds
) -> jax.Array:
    """E[T_inf(s)] for all splits s=0..N; differentiable w.r.t. everything.

    Mirrors latency.expected_time_all_splits in jnp.  The cumulative products
    / sums are the ``lax``-level scan form of Bellman-Ford on the chain DAG:
    dist[s] = dist[s-1] + w_e[s], relaxed once per vertex in topological
    order, which is all a DAG needs.
    """
    t_net = alpha * 8.0 / bandwidth_bps
    t_e = gamma * t_c
    surv = jnp.cumprod(1.0 - p)  # surv[i] = alive after v_i's branch
    reach = jnp.concatenate([jnp.ones((1,), surv.dtype), surv[:-1]])

    w_e = t_e * reach
    if branch_t_c is not None:
        # Branch head at layer k is paid by splits s >= k+1 (Fig. 2(c)).
        w_b = gamma * branch_t_c * reach
        w_e = w_e + jnp.concatenate([jnp.zeros((1,), w_b.dtype), w_b[:-1]])
    cum_edge = jnp.cumsum(w_e)

    tail_cloud = jnp.concatenate(
        [jnp.cumsum(t_c[::-1])[::-1][1:], jnp.zeros((1,), t_c.dtype)]
    )
    surv_at_cut = reach  # branch at the cut is not evaluated
    cost = cum_edge + surv_at_cut * (t_net + tail_cloud)
    n = t_c.shape[0] - 1
    return cost.at[n].set(cum_edge[n])


@jax.jit
def solve_chain_jax(
    t_c: jax.Array,
    alpha: jax.Array,
    p: jax.Array,
    gamma: jax.Array,
    bandwidth_bps: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """(optimal split s*, E[T(s*)]).  vmap over any argument for sweeps."""
    costs = chain_costs_jax(t_c, alpha, p, gamma, bandwidth_bps)
    s = jnp.argmin(costs)
    return s, costs[s]
