"""The paper's contribution: BranchyNet partitioning as shortest path.

Public API:

    from repro.core import (
        BranchSpec, CostProfile, NetworkProfile, PartitionPlan, UPLINK_PRESETS,
        Partitioner, build_cost_profile,
        expected_time, expected_time_all_splits,
        build_partition_graph, dijkstra, shortest_path_plan, brute_force_split,
        solve_chain_jax, chain_costs_jax,
        normalized_entropy, calibrate_exit_probs, threshold_sweep,
        analyze_layer_costs, measure_layer_times, HardwareSpec, TPU_V5E,
    )
"""

from repro.core.calibration import (
    CalibrationResult,
    calibrate_exit_probs,
    exit_mask,
    normalized_entropy,
    threshold_sweep,
)
from repro.core.dag import DagCostModel, DagNode, chain_as_dag, min_cut_partition
from repro.core.graph import Graph, build_partition_graph
from repro.core.multitier import (
    MultiTierPlan,
    TierSpec,
    expected_time_multitier,
    solve_multitier,
)
from repro.core.latency import expected_time, expected_time_all_splits, plan_from_split
from repro.core.partitioner import Partitioner, build_cost_profile
from repro.core.profiler import (
    TPU_V5E,
    HardwareSpec,
    LayerCost,
    analyze_layer_costs,
    decode_layer_fns,
    measure_layer_times,
    output_bytes,
    profile_decode_layers,
)
from repro.core.shortest_path import (
    brute_force_split,
    chain_costs_jax,
    dijkstra,
    shortest_path_plan,
    solve_chain_jax,
)
from repro.core.types import (
    UPLINK_PRESETS,
    BranchSpec,
    CostProfile,
    NetworkProfile,
    PartitionPlan,
)

__all__ = [
    "BranchSpec",
    "CostProfile",
    "NetworkProfile",
    "PartitionPlan",
    "UPLINK_PRESETS",
    "Partitioner",
    "build_cost_profile",
    "expected_time",
    "expected_time_all_splits",
    "plan_from_split",
    "Graph",
    "build_partition_graph",
    "DagCostModel",
    "DagNode",
    "chain_as_dag",
    "min_cut_partition",
    "TierSpec",
    "MultiTierPlan",
    "solve_multitier",
    "expected_time_multitier",
    "dijkstra",
    "shortest_path_plan",
    "brute_force_split",
    "solve_chain_jax",
    "chain_costs_jax",
    "CalibrationResult",
    "normalized_entropy",
    "exit_mask",
    "calibrate_exit_probs",
    "threshold_sweep",
    "HardwareSpec",
    "TPU_V5E",
    "LayerCost",
    "analyze_layer_costs",
    "decode_layer_fns",
    "measure_layer_times",
    "profile_decode_layers",
    "output_bytes",
]
