"""Construction of the partitioning graph ``G'_BDNN`` (paper Sec. V, Eq. 7-8).

Vertices (for a main branch of N layers):

  * ``input`` / ``output`` — the two virtual terminals;
  * ``e:i``   — main layer ``v_i`` processed on the edge (``P^e`` chain);
  * ``b:k``   — side branch ``b_k`` on the edge (interleaved into ``P^e``);
  * ``a:i``   — auxiliary cut vertex ``v_i^{*e}`` (paper's orange vertices);
  * ``c:i``   — main layer ``v_i`` processed in the cloud (``P^c`` chain);
  * ``t:out`` — the virtual ``v^{*c}`` predecessor of ``output`` carrying the
    epsilon link that disambiguates the p == 1 case.

Link weights follow Eq. 7, scaled per Eq. 8 by the probability that the
sample is still alive when the link is traversed (see latency.py for why the
multiplier is the survival probability ``prod_{j<=k}(1-p_j)``, not the
literal ``p_Y(k)``).

A shortest ``input -> output`` path therefore costs exactly
``E[T_inf(s)]`` (latency.expected_time) for the split ``s`` it encodes, up to
the epsilon tie-breaker.  ``tests/test_shortest_path.py`` asserts the
equivalence property against the closed form and brute force.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.types import CostProfile

__all__ = ["Graph", "build_partition_graph", "EPSILON"]

#: Paper Sec. V: "The weight epsilon must be a very small value, to not
#: interfere with the result of the shortest path problem."
EPSILON = 1e-12


@dataclasses.dataclass
class Graph:
    """Minimal adjacency-list digraph with non-negative float weights."""

    adj: dict[str, list[tuple[str, float]]] = dataclasses.field(default_factory=dict)

    def add_vertex(self, v: str) -> None:
        self.adj.setdefault(v, [])

    def add_link(self, u: str, v: str, w: float) -> None:
        if w < 0:
            raise ValueError(f"negative link weight {w} on ({u},{v})")
        self.add_vertex(u)
        self.add_vertex(v)
        self.adj[u].append((v, float(w)))

    @property
    def num_vertices(self) -> int:
        return len(self.adj)

    @property
    def num_links(self) -> int:
        return sum(len(out) for out in self.adj.values())


def split_of_path(path: list[str]) -> int:
    """Recover the partition layer ``s`` encoded by an input->output path."""
    edge_layers = [int(v.split(":")[1]) for v in path if v.startswith("e:")]
    return max(edge_layers) if edge_layers else 0


def build_partition_graph(profile: CostProfile) -> Graph:
    """Build ``G'_BDNN`` for a cost profile.

    Weight conventions (Eq. 7), with ``surv(i)`` the probability the sample
    is alive after the branch of layer ``i`` (1 if no branch):

      * edge-chain link out of ``v_i^e``            -> ``surv``-scaled t_i^e
      * cloud-chain link out of ``v_i^c``           -> ``surv``-scaled t_i^c
      * ``input -> c:1``                            -> t_input^net  (Eq. 7 row 3)
      * ``input -> e:1``                            -> 0            (edge-only entry)
      * ``a:i -> c:{i+1}``                          -> surv-scaled t_i^net (cut!)
      * ``a:i -> next edge vertex``                 -> 0            (Eq. 7 row 5)
      * ``c:N -> t:out -> output``                  -> epsilon tie-break
      * ``e:N -> output``                           -> 0 (edge-only exit)

    Side-branch vertices ``b:k`` are interleaved on the edge chain between
    ``a:k`` and ``e:{k+1}``; their outgoing weight is the (optional) branch
    compute time; traversing past them applies the (1-p_k) survival scaling
    to everything downstream.
    """
    n = profile.num_layers
    t_e = profile.t_e
    t_c = profile.t_c
    t_net = profile.t_net
    branches = {b.after_layer: b for b in profile.branches}

    g = Graph()
    g.add_vertex("input")
    g.add_vertex("output")

    # --- cloud chain P^c: cloud-only entry costs the raw-input upload.
    g.add_link("input", "c:1", t_net[0])
    for i in range(1, n):
        g.add_link(f"c:{i}", f"c:{i + 1}", t_c[i])
    g.add_link(f"c:{n}", "t:out", t_c[n])
    g.add_link("t:out", "output", EPSILON)

    # --- edge chain P^e with auxiliary cut vertices and branch vertices.
    g.add_link("input", "e:1", 0.0)
    alive = 1.0  # survival probability at the current position in the chain
    for i in range(1, n + 1):
        # Processing v_i on the edge; every traversal this deep is already
        # conditioned on surviving all branches before v_i.
        w_proc = alive * t_e[i]
        g.add_link(f"e:{i}", f"a:{i}", w_proc)
        if i < n:
            # Cut here: ship alpha_i to the cloud, continue on the cloud chain.
            g.add_link(f"a:{i}", f"c:{i + 1}", alive * t_net[i])
        else:
            # Edge-only exit.
            g.add_link(f"a:{n}", "output", 0.0)
        b = branches.get(i)
        if b is not None and i < n:
            w_b = (
                alive * profile.gamma * b.compute_time_cloud
                if profile.include_branch_compute
                else 0.0
            )
            g.add_link(f"a:{i}", f"b:{i}", 0.0)
            alive *= 1.0 - b.exit_prob
            g.add_link(f"b:{i}", f"e:{i + 1}", w_b)
        elif i < n:
            g.add_link(f"a:{i}", f"e:{i + 1}", 0.0)

    # Cloud-chain weights after a branch position are *not* rescaled on the
    # cloud chain itself: the cloud never evaluates branches, so the cloud
    # chain entered from ``input`` keeps full weights.  The survival scaling
    # of a *partitioned* path is carried entirely by the prefix treatment
    # above... except that the cloud tail after a cut must also be scaled.
    # We achieve that with dedicated scaled tail chains per cut point, see
    # below: replace the naive a:i -> c:{i+1} links with scaled tails.
    return _rescale_cloud_tails(g, profile)


def _rescale_cloud_tails(g: Graph, profile: CostProfile) -> Graph:
    """Replace each cut link ``a:i -> c:{i+1}`` with a scaled private tail.

    A path that cuts after ``v_i`` has survival ``surv(i-1)`` (branches up to
    ``b_{i-1}`` were evaluated on the edge; the branch at the cut is skipped,
    Fig. 2(c)).  The whole remaining cost — transfer *and* the cloud tail —
    must be scaled by it (Eq. 5's ``(1 - p_Y(k))`` factor).  Sharing the
    unscaled ``P^c`` chain would lose that, so each cut gets its own scaled
    copy of the tail; this keeps the graph linear in size: O(N^2) links for
    N layers, still trivially Dijkstra-able for any realistic depth, and an
    exact materialization of Eq. 8's "weights after the branch are scaled".
    """
    n = profile.num_layers
    t_c = profile.t_c
    t_net = profile.t_net
    surv = profile.survival_after()

    # Drop the naive cut links added during construction.
    for i in range(1, n):
        g.adj[f"a:{i}"] = [(v, w) for v, w in g.adj[f"a:{i}"] if not v.startswith("c:")]

    for i in range(1, n):
        alive = surv[i - 1]  # branch at the cut is not evaluated
        g.add_link(f"a:{i}", f"ct:{i}:{i + 1}", alive * t_net[i])
        for j in range(i + 1, n + 1):
            src = f"ct:{i}:{j}"
            if j < n:
                g.add_link(src, f"ct:{i}:{j + 1}", alive * t_c[j])
            else:
                g.add_link(src, "t:out", alive * t_c[n])
    return g
