"""DAG partitioning — the paper's stated future work, implemented.

The paper (Sec. VII): "As future work, our first goal is to extend our
proposal to handle also DAG topology DNN."  For general DAGs the chain
shortest-path construction no longer applies; following DADS [6] the
minimum-expected-time partition of a DAG is a minimum s-t cut:

  * node v on the edge device pays t_v^e, in the cloud pays t_v^c;
  * a data dependency (u, v) crossing edge->cloud pays t_u^net;
  * construction: arc (s, v) with capacity t_v^c (cut when v is assigned
    to the CLOUD side), arc (v, t) with capacity t_v^e (cut when v stays
    on the EDGE side), arc (u, v) with capacity t_u^net and an infinite
    reverse arc (v, u) forbidding cloud->edge data flow.

Early-exit weighting: when the DAG is a chain-with-branches, weights are
pre-scaled by the survival probability exactly as in the chain solver; for
general DAGs the caller provides already-scaled costs (exit semantics on
arbitrary DAGs are application-specific).

Max-flow is Dinic's algorithm — graphs here are model graphs (tens to a
few hundred nodes), so this is control-plane trivial.
"""

from __future__ import annotations

import collections
import dataclasses

import numpy as np

__all__ = ["DagNode", "DagCostModel", "min_cut_partition", "chain_as_dag"]

INF = float("inf")


@dataclasses.dataclass(frozen=True)
class DagNode:
    name: str
    t_edge: float
    t_cloud: float


@dataclasses.dataclass
class DagCostModel:
    nodes: dict[str, DagNode]
    links: list[tuple[str, str, float]]  # (u, v, transfer_time u->v)
    input_upload_time: float = 0.0  # raw-input transfer if the first nodes
    #                                 run in the cloud (alpha_0 / B)
    input_consumers: tuple[str, ...] = ()


class _Dinic:
    def __init__(self):
        self.g: dict[str, list] = collections.defaultdict(list)

    def add(self, u, v, cap):
        # forward edge [v, cap, index_of_reverse], reverse with 0 cap
        self.g[u].append([v, cap, len(self.g[v])])
        self.g[v].append([u, 0.0, len(self.g[u]) - 1])

    def max_flow(self, s, t) -> float:
        flow = 0.0
        while True:
            level = {s: 0}
            dq = collections.deque([s])
            while dq:
                u = dq.popleft()
                for v, cap, _ in self.g[u]:
                    if cap > 1e-12 and v not in level:
                        level[v] = level[u] + 1
                        dq.append(v)
            if t not in level:
                return flow
            it = {u: 0 for u in self.g}

            def dfs(u, f):
                if u == t:
                    return f
                while it[u] < len(self.g[u]):
                    e = self.g[u][it[u]]
                    v, cap, rev = e
                    if cap > 1e-12 and level.get(v, -1) == level[u] + 1:
                        d = dfs(v, min(f, cap))
                        if d > 1e-12:
                            e[1] -= d
                            self.g[v][rev][1] += d
                            return d
                    it[u] += 1
                return 0.0

            while True:
                f = dfs(s, INF)
                if f <= 1e-12:
                    break
                flow += f

    def reachable(self, s) -> set[str]:
        seen = {s}
        dq = collections.deque([s])
        while dq:
            u = dq.popleft()
            for v, cap, _ in self.g[u]:
                if cap > 1e-12 and v not in seen:
                    seen.add(v)
                    dq.append(v)
        return seen


def min_cut_partition(model: DagCostModel) -> tuple[set[str], set[str], float]:
    """Returns (edge_set, cloud_set, expected_time)."""
    net = _Dinic()
    s, t = "__source__", "__sink__"
    for name, node in model.nodes.items():
        net.add(s, name, node.t_cloud)  # cut -> v in cloud pays t_cloud
        net.add(name, t, node.t_edge)  # cut -> v on edge pays t_edge
    for u, v, tx in model.links:
        net.add(u, v, tx)
        net.add(v, u, INF)  # forbid cloud -> edge data flow
    # Raw-input upload: the sample materializes on the edge device (paper
    # Sec. IV-C); pin a virtual input node to the edge side and charge the
    # upload once if any consumer lands in the cloud (via a shared hub).
    if model.input_consumers and model.input_upload_time > 0:
        net.add(s, "__input__", INF)  # cloud assignment impossible
        net.add("__input__", t, 0.0)  # free on the edge
        net.add("__input__", "__uphub__", model.input_upload_time)
        net.add("__uphub__", "__input__", INF)
        for v in model.input_consumers:
            net.add("__uphub__", v, INF)
            net.add(v, "__uphub__", INF)
    cost = net.max_flow(s, t)
    edge_side = net.reachable(s) - {s}
    edge = {n for n in model.nodes if n in edge_side}
    cloud = set(model.nodes) - edge
    return edge, cloud, cost


def chain_as_dag(t_c, alpha, bandwidth_bps: float, gamma: float) -> DagCostModel:
    """Lift the paper's chain model into the DAG solver (for cross-checks:
    with no branches, min-cut and shortest path must agree)."""
    t_c = np.asarray(t_c, float)
    alpha = np.asarray(alpha, float)
    n = len(t_c) - 1
    nodes = {
        f"v{i}": DagNode(f"v{i}", gamma * t_c[i], t_c[i]) for i in range(1, n + 1)
    }
    links = [
        (f"v{i}", f"v{i + 1}", alpha[i] * 8.0 / bandwidth_bps)
        for i in range(1, n)
    ]
    return DagCostModel(
        nodes=nodes,
        links=links,
        input_upload_time=alpha[0] * 8.0 / bandwidth_bps,
        input_consumers=("v1",),
    )
