import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, record memory/cost/collective analysis.

The two lines above MUST precede any other import (jax locks the device
count at first init) — this is why this module sets XLA_FLAGS globally and
nothing else in the repo does.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # everything
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_8b --shape decode_32k
    PYTHONPATH=src python -m repro.launch.dryrun --multi-pod     # 2x16x16
    ... --force     re-run combos that already have a result JSON

Results land in results/dryrun/<arch>__<shape>__<mesh>.json and feed
EXPERIMENTS.md §Dry-run and §Roofline (benchmarks/roofline.py).
"""

import argparse
import dataclasses
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, INPUT_SHAPES, InputShape, ModelConfig
from repro.configs import get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (
    cache_specs,
    config_for_shape,
    decode_input_specs,
    param_specs,
    prefill_input_specs,
    shape_supported,
    train_batch_specs,
)
from repro.models import model as M
from repro.sharding.ctx import activation_sharding
from repro.sharding.policy import make_policy
from repro.training.optimizer import make_optimizer
from repro.training.train_loop import init_train_state, make_train_step

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def parse_collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-collective-type payload bytes per device, from optimized HLO.

    For each collective instruction we take the largest typed shape on the
    line (operand or result) as the payload that crosses the interconnect —
    exact for all-reduce/all-to-all/permute, and the gathered/full size for
    all-gather / reduce-scatter (the quantity the ICI actually carries,
    up to the (n-1)/n ring factor which we fold into the roofline constant).
    """
    out = {c: 0.0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*.*?\b(" + "|".join(_COLLECTIVES) + r")",
                     stripped)
        if not m:
            continue
        op = m.group(1)
        best = 0.0
        for dt, dims in shape_re.findall(stripped):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            best = max(best, n * _DTYPE_BYTES[dt])
        out[op] += best
        counts[op] += 1
    out["_counts"] = counts  # type: ignore[assignment]
    return out


def _mem_stats(compiled) -> dict:
    ma = compiled.memory_analysis()
    if ma is None:
        return {}
    return {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "peak_bytes_est": ma.argument_size_in_bytes
        + ma.temp_size_in_bytes
        + ma.output_size_in_bytes
        - ma.alias_size_in_bytes,
    }


def _cost_stats(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    ca = ca or {}
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }


# ---------------------------------------------------------------------------
def build_step(cfg: ModelConfig, shape: InputShape, mesh, moe_dispatch: str):
    """Returns (jitted fn, abstract args tuple) for this workload kind."""
    policy = make_policy(mesh, cfg)
    p_shapes = param_specs(cfg)
    p_shard = policy.params_shardings(p_shapes)

    if shape.kind == "train":
        opt = make_optimizer(cfg.optimizer)
        # Cap accumulation so each microbatch covers all batch shards.
        batch_shards = 1
        for a in ("pod", "data"):
            if a in mesh.shape:
                batch_shards *= mesh.shape[a]
        accum = max(1, min(cfg.grad_accum, shape.global_batch // batch_shards))
        step_fn = make_train_step(cfg, opt, moe_dispatch=moe_dispatch, accum=accum)
        batch = train_batch_specs(cfg, shape)
        state_shapes = jax.eval_shape(lambda p: init_train_state(p, opt), p_shapes)
        state_shard = {
            "params": p_shard,
            "opt": policy.opt_state_shardings(p_shapes, cfg.optimizer),
            "step": policy.replicated(),
        }
        # Explicit out_shardings: without them XLA may choose replicated
        # outputs for updated params, breaking donation aliasing (observed
        # +20 GB/dev on the 76B config).
        fn = jax.jit(
            step_fn,
            in_shardings=(state_shard, policy.data_shardings(batch)),
            out_shardings=(state_shard, None),
            donate_argnums=0,
        )
        return fn, (state_shapes, batch)

    if shape.kind == "prefill":
        inputs = prefill_input_specs(cfg, shape)
        caches = cache_specs(cfg, shape)
        cache_shard = policy.cache_shardings(caches)
        fn = jax.jit(
            lambda params, inp, c: M.prefill(
                params, inp, cfg, c, moe_dispatch=moe_dispatch
            ),
            in_shardings=(
                p_shard,
                policy.data_shardings(inputs),
                cache_shard,
            ),
            out_shardings=(None, cache_shard),
            donate_argnums=2,
        )
        return fn, (p_shapes, inputs, caches)

    # decode
    io = decode_input_specs(cfg, shape)
    caches = cache_specs(cfg, shape)
    fn = jax.jit(
        lambda params, tok, pos, c: M.decode_step(
            params, tok, pos, c, cfg, moe_dispatch=moe_dispatch
        ),
        in_shardings=(
            p_shard,
            policy.data_shardings({"t": io["token"]})["t"],
            policy.replicated(),
            policy.cache_shardings(caches),
        ),
        donate_argnums=3,
    )
    return fn, (p_shapes, io["token"], io["pos"], caches)


def run_one(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    moe_dispatch: str = "einsum",
    out_dir: Path = RESULTS_DIR,
    force: bool = False,
    tag: str = "",
    overrides: dict | None = None,
) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    suffix = f"__{tag}" if tag else ""
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    shape = INPUT_SHAPES[shape_name]
    cfg0 = get_config(arch)
    ok, why = shape_supported(cfg0, shape)
    rec: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "kind": shape.kind,
        "moe_dispatch": moe_dispatch,
        "tag": tag,
    }
    if not ok:
        rec.update(status="skipped", reason=why)
        _write(out_path, rec)
        return rec

    cfg = config_for_shape(cfg0, shape)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
        rec["overrides"] = {k: str(v) for k, v in overrides.items()}
    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        t0 = time.time()
        fn, args = build_step(cfg, shape, mesh, moe_dispatch)
        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        with mesh, activation_sharding(mesh, batch_axes):
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0
        hlo = compiled.as_text()
        from repro.launch.hlo_analysis import analyze_hlo

        hlo_stats = analyze_hlo(hlo)
        rec.update(
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory=_mem_stats(compiled),
            cost=_cost_stats(compiled),
            # Trip-count-corrected per-device totals (hlo_analysis.py):
            # XLA's cost_analysis counts while bodies once.
            dot_flops=hlo_stats["dot_flops"],
            hbm_bytes=hlo_stats["hbm_bytes"],
            collectives={**hlo_stats["collectives"], "_counts": hlo_stats["counts"]},
            num_params=cfg.num_params(),
            active_params=cfg.active_params(),
            sliding_window=cfg.sliding_window,
            hlo_bytes=len(hlo),
        )
        print(compiled.memory_analysis())
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-4000:])
    _write(out_path, rec)
    return rec


def _write(path: Path, rec: dict) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(rec, indent=2, default=str))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS))
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--moe-dispatch", default="einsum",
                    choices=["einsum", "onehot_small"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="", help="suffix for experiment variants")
    ap.add_argument("--set", action="append", default=[],
                    help="config override field=value (perf experiments)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        if v in ("true", "True"):
            v = True
        elif v in ("false", "False"):
            v = False
        elif v.isdigit():
            v = int(v)
        overrides[k] = v

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_ok = n_err = n_skip = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_one(arch, shape, mp, args.moe_dispatch,
                              force=args.force, tag=args.tag,
                              overrides=overrides or None)
                status = rec["status"]
                n_ok += status == "ok"
                n_err += status == "error"
                n_skip += status == "skipped"
                mem = rec.get("memory", {}).get("peak_bytes_est")
                mem_s = f"{mem / 1e9:.2f} GB/dev" if mem else "-"
                print(
                    f"[{status:7s}] {arch:20s} {shape:12s} "
                    f"{'2x16x16' if mp else '16x16':8s} {mem_s}"
                    + (f"  ERR: {rec.get('error', '')[:120]}" if status == "error" else "")
                )
    print(f"\nok={n_ok} skipped={n_skip} errors={n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
