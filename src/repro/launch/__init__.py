"""repro.launch — see module docstrings."""
