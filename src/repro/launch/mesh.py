"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run must set XLA_FLAGS
before any jax initialization.

Target fleet: TPU v5e, 256 chips/pod (16x16), 2 pods = 512 chips.
Axes: ("data", "model") single-pod; ("pod", "data", "model") multi-pod.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "mesh_axis_sizes"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1x1 mesh over the real local device (CPU tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict[str, int]:
    return dict(mesh.shape)
