"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run must set XLA_FLAGS
before any jax initialization.

Target fleet: TPU v5e, 256 chips/pod (16x16), 2 pods = 512 chips.
Axes: ("data", "model") single-pod; ("pod", "data", "model") multi-pod.
"""

from __future__ import annotations

import numpy as np

import jax

__all__ = [
    "make_production_mesh",
    "make_local_mesh",
    "mesh_axis_sizes",
    "mesh_devices",
]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(*, data: int | None = None, model: int | None = None):
    """("data", "model") mesh over the local devices.

    Defaults put every device on the "model" axis (a (1, n) mesh — tensor
    parallelism across whatever is available, which is the sharded-tier
    serving shape).  ``data=`` / ``model=`` override either axis so tests
    can build e.g. a (2, 4) mesh on 8 virtual CPU devices; an unset axis
    absorbs the remaining devices.
    """
    n = len(jax.devices())
    if data is None and model is None:
        data, model = 1, n
    elif data is None:
        data = max(n // model, 1)
    elif model is None:
        model = max(n // data, 1)
    if data < 1 or model < 1:
        raise ValueError(f"mesh axes must be >= 1: data={data}, model={model}")
    if data * model > n:
        raise ValueError(
            f"requested mesh ({data}, {model}) = {data * model} devices, "
            f"but only {n} are available (set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N before "
            f"jax initializes to virtualize more)"
        )
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict[str, int]:
    return dict(mesh.shape)


def mesh_devices(mesh) -> int:
    """Shard width a tier running on ``mesh`` has: the total device count
    across every mesh axis.  This is the ``TierSpec.devices`` /
    ``TierSegment.devices`` term of the sharding-aware partition cost
    (compute scales 1/devices, plus the intra-tier collective term)."""
    if mesh is None:
        return 1
    return int(np.prod(list(mesh_axis_sizes(mesh).values()), dtype=np.int64))
