"""Trip-count-aware analysis of optimized HLO text.

XLA's ``cost_analysis()`` counts while-loop bodies ONCE (verified: a scanned
matmul reports 1/L of the unrolled flops), so any scan-over-layers program
under-reports flops/bytes/collectives by the loop trip counts.  The
optimized HLO, however, annotates every counted loop with
``backend_config={"known_trip_count":{"n":"K"}}`` — so we reconstruct true
per-step totals by walking the call graph and multiplying each
computation's costs by the product of enclosing trip counts.

Extracted per program:
  * collective payload bytes per type (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute), using the largest
    typed shape on the instruction line (operand or result);
  * matmul flops: 2 * prod(dot output dims) * prod(contracting dims)
    — the MXU-relevant compute, exact for dense/MoE trunks;
  * per-type instruction counts.

All numbers are per device (the module is the SPMD-partitioned one).
"""

from __future__ import annotations

import functools
import json
import re

__all__ = ["analyze_hlo", "COLLECTIVES"]

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_CALLED_ONE = re.compile(r"(?:body|condition|to_apply|calls)=%?([\w.\-]+)")
_CALLED_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP = re.compile(r'trip_count\\?":\{\\?"n\\?":\\?"(\d+)')


def _shape_bytes(dt: str, dims: str) -> float:
    if dt not in _DTYPE_BYTES:
        return 0.0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return float(n) * _DTYPE_BYTES[dt]


def _parse_computations(hlo: str):
    """name -> (instruction lines, local name->typed-shape map); + ENTRY."""
    comps: dict[str, tuple[list[str], dict]] = {}
    entry = None
    cur: list[str] | None = None
    shapes: dict[str, tuple[str, str]] | None = None
    hdr_param = re.compile(r"([\w.\-]+):\s*(\w+)\[([\d,]*)\]")
    instr = re.compile(r"^%?([\w.\-]+)\s*=\s*\(?\s*(\w+)\[([\d,]*)\]")
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        m = _COMP_HDR.match(s)
        if m and s.endswith("{"):
            name = m.group(1)
            cur, shapes = [], {}
            comps[name] = (cur, shapes)
            for pn, dt, dims in hdr_param.findall(s):
                shapes[pn] = (dt, dims)
            if s.startswith("ENTRY"):
                entry = name
            continue
        if s == "}":
            cur = shapes = None
            continue
        if cur is not None and "=" in s:
            cur.append(s)
            im = instr.match(s)
            if im:
                shapes[im.group(1)] = (im.group(2), im.group(3))
    return comps, entry


def _operand_shapes(line: str, shapes: dict) -> list[tuple[str, str]]:
    """Typed shapes of an instruction's operands.

    Optimized HLO types every operand inline (``dot(f32[4,256] %a, ...)``),
    so the typed shapes inside the first paren group are authoritative;
    name-map lookup is the fallback for untyped (older-style) operand
    lists.  Splitting must not happen on commas — shapes contain them.
    """
    m = re.search(r"[\w\-]+\(([^)]*)", line)
    if not m:
        return []
    args = m.group(1)
    typed = _SHAPE_RE.findall(args)
    if typed:
        return typed
    out = []
    for tok in args.split(","):
        nm = tok.strip().lstrip("%")
        if nm in shapes:
            out.append(shapes[nm])
    return out


def _dot_flops(line: str, shapes: dict) -> float:
    """2 * prod(output) * prod(lhs contracting dims)."""
    if " dot(" not in line:
        return 0.0
    out = _SHAPE_RE.search(line.split("=", 1)[1])
    if not out:
        return 0.0
    out_elems = 1
    for d in out.group(2).split(","):
        if d:
            out_elems *= int(d)
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    # Operand list only (strip the result type left of " dot(").
    ops = _operand_shapes("dot(" + line.split(" dot(", 1)[1], shapes)
    lhs_shape = None
    if ops:
        lhs_shape = [int(d) for d in ops[0][1].split(",") if d]
    contract = 1
    if mc and lhs_shape:
        for idx in mc.group(1).split(","):
            if idx:
                contract *= lhs_shape[int(idx)]
    return 2.0 * out_elems * contract


def analyze_hlo(hlo: str) -> dict:
    comps, entry = _parse_computations(hlo)
    if entry is None:
        return {"collectives": {c: 0.0 for c in COLLECTIVES},
                "dot_flops": 0.0, "counts": {}}

    # Per-computation direct costs and calls.
    _NO_TRAFFIC = {
        "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
        "after-all", "iota",
    }
    direct: dict[str, dict] = {}
    calls: dict[str, list[tuple[str, float, str]]] = {}
    for name, (lines, shapes) in comps.items():
        colls = {c: 0.0 for c in COLLECTIVES}
        counts = {c: 0 for c in COLLECTIVES}
        flops = 0.0
        bytes_ = 0.0
        edges: list[tuple[str, float, str]] = []
        for line in lines:
            # Result type may be a tuple containing spaces: match the op
            # name as the token immediately before the first '('.
            opm = re.match(
                r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?:\([^)]*\)|\S+)\s+([\w\-]+)\(",
                line,
            )
            opcode = opm.group(1) if opm else ""
            if opcode in COLLECTIVES or (
                opcode.endswith("-start") and opcode[:-6] in COLLECTIVES
            ):
                op = opcode[:-6] if opcode.endswith("-start") else opcode
                cands = [
                    _shape_bytes(dt, dims)
                    for dt, dims in _SHAPE_RE.findall(line.split("(")[0])
                ] + [_shape_bytes(dt, dims) for dt, dims in _operand_shapes(line, shapes)]
                colls[op] += max(cands, default=0.0)
                counts[op] += 1
            flops += _dot_flops(line, shapes)
            # HBM-traffic proxy: every produced value is written once and
            # read ~once downstream -> 2 * result bytes.  Fusion internals
            # are excluded (the fusion node's own result covers them).
            # In-place ops (dynamic-update-slice on donated buffers — the
            # KV-cache write) only touch the updated slice, not the result.
            if opcode and opcode not in _NO_TRAFFIC:
                if opcode == "dynamic-update-slice":
                    ops = _operand_shapes(line, shapes)
                    if len(ops) >= 2:
                        bytes_ += 2.0 * _shape_bytes(*ops[1])
                        continue
                res = _SHAPE_RE.findall(line.split("=", 1)[1].split("(", 1)[0])
                bytes_ += 2.0 * sum(_shape_bytes(dt, d) for dt, d in res)
            callees = _CALLED_ONE.findall(line)
            for group in _CALLED_BRANCHES.findall(line):
                callees.extend(c.strip().lstrip("%") for c in group.split(","))
            if callees:
                trip = 1.0
                tm = _TRIP.search(line)
                if tm and " while(" in line:
                    trip = float(tm.group(1))
                kind = "fusion" if opcode == "fusion" else "control"
                for callee in callees:
                    if callee in comps:
                        # condition runs trip+1 times; treat as trip.
                        edges.append((callee, trip, kind))
        direct[name] = {
            "colls": colls, "counts": counts, "flops": flops, "bytes": bytes_,
        }
        calls[name] = edges

    @functools.lru_cache(maxsize=None)
    def total(name: str) -> tuple:
        d = direct[name]
        colls = dict(d["colls"])
        counts = dict(d["counts"])
        flops = d["flops"]
        bytes_ = d["bytes"]
        for callee, mult, kind in calls[name]:
            if callee == name:
                continue
            sub = total(callee)
            for c in COLLECTIVES:
                colls[c] += mult * sub[0][c]
                counts[c] += int(mult * sub[1][c])
            flops += mult * sub[2]
            if kind != "fusion":
                bytes_ += mult * sub[3]
        return colls, counts, flops, bytes_

    colls, counts, flops, bytes_ = total(entry)
    return {
        "collectives": colls,
        "dot_flops": flops,
        "hbm_bytes": bytes_,
        "counts": counts,
    }
