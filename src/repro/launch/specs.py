"""Abstract input specs (ShapeDtypeStruct) for every (arch x input shape).

No device memory is ever allocated here — these are the stand-ins the
dry-run lowers against.  ``long_500k`` swaps in the sub-quadratic config
variant (sliding-window attention for dense/MoE/VLM/hybrid-shared-attn;
SSM state is O(1) natively); whisper skips it (DESIGN.md Sec. 4).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig

__all__ = [
    "LONG_CONTEXT_WINDOW",
    "shape_supported",
    "config_for_shape",
    "train_batch_specs",
    "prefill_input_specs",
    "decode_input_specs",
]

LONG_CONTEXT_WINDOW = 8192

SDS = jax.ShapeDtypeStruct


def shape_supported(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """(supported?, reason-if-not)."""
    if shape.name == "long_500k" and cfg.arch_type == "audio":
        return False, (
            "enc-dec ASR decoder has a hard cross-attention context (1500 "
            "frames); no sub-quadratic self-attention story at 524k tokens "
            "(DESIGN.md Sec. 4 skip)"
        )
    return True, ""


def config_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Long-context decode uses the sliding-window variant for attention
    archs; everything else runs the published config unchanged."""
    if shape.name == "long_500k" and cfg.arch_type != "ssm":
        if cfg.sliding_window == 0:
            cfg = dataclasses.replace(cfg, sliding_window=LONG_CONTEXT_WINDOW)
    return cfg


def _token_batch(cfg: ModelConfig, batch: int, seq: int) -> dict:
    out = {}
    if cfg.frontend == "vision":
        text = seq - cfg.num_patches
        assert text > 0, "seq_len must exceed the visual prefix"
        out["tokens"] = SDS((batch, text), jnp.int32)
        out["patch_embeds"] = SDS((batch, cfg.num_patches, cfg.d_model), jnp.float32)
    elif cfg.frontend == "audio":
        out["tokens"] = SDS((batch, seq), jnp.int32)
        out["frame_embeds"] = SDS(
            (batch, cfg.encoder_seq_len, cfg.d_model), jnp.float32
        )
    else:
        out["tokens"] = SDS((batch, seq), jnp.int32)
    return out


def train_batch_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    batch = _token_batch(cfg, shape.global_batch, shape.seq_len)
    batch["labels"] = SDS(batch["tokens"].shape, jnp.int32)
    return batch


def prefill_input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    return _token_batch(cfg, shape.global_batch, shape.seq_len)


def decode_input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """Specs for (token, pos) of one decode step; caches come from
    jax.eval_shape over model.init_caches."""
    return {
        "token": SDS((shape.global_batch, 1), jnp.int32),
        "pos": SDS((), jnp.int32),
    }


def cache_specs(cfg: ModelConfig, shape: InputShape):
    """Abstract cache tree via eval_shape (no allocation)."""
    from repro.models import model as M

    return jax.eval_shape(
        lambda: M.init_caches(cfg, shape.global_batch, shape.seq_len)
    )


def param_specs(cfg: ModelConfig):
    from repro.models import model as M

    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda: M.init_params(key, cfg))
