"""Continuous-batching request scheduler (serving/scheduler.py) and its
supporting refactors:

  * THE invariant: a request admitted into a recycled KV slot produces a
    token/exit-trajectory bitwise identical to running it alone from its
    admission state — K in {1, 2, 3}, compaction on/off, the Pallas
    kernel path in interpret mode, and a Mamba2 (SSD) trunk;
  * row-targeted prefill writes == fresh solo prefill caches, per-row
    reset, and the one-sync-per-decode-step contract under admission /
    retirement churn;
  * bucket-hint sanity across a mass-retirement + re-admission wave
    (buckets shrink to the live width, recover through a counted
    overflow retry);
  * gang (lock-step) vs continuous admission policies, TTFT / latency
    accounting, stop_on_exit retirement;
  * the occupancy-weighted expected-batch term in core.multitier and its
    threading through est_latency_s and the RepartitionController;
  * RepartitionController.probe_sample_frac: sampled epsilon probes with
    unbiased arrival accounting via branch_probe_mask;
  * core.profiler.profile_decode_layers: kernel-aware per-layer decode
    costs (interpret mode off-TPU).
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import LayerCost, build_cost_profile, profile_decode_layers
from repro.core.multitier import (
    TierSpec,
    bucket_for,
    expected_time_multitier,
    solve_multitier,
)
from repro.models import model as M
from repro.serving import (
    MultiTierServer,
    PartitionedServer,
    RepartitionController,
    RequestScheduler,
    ServingEngine,
    TierExecutor,
    segments_for_cuts,
)


@pytest.fixture(scope="module")
def deep_model():
    """4 trunk layers, branches after v_1 and v_3, threshold calibrated to
    a mixed exit regime (as in test_compaction / test_kernel_runtime)."""
    cfg = dataclasses.replace(
        get_smoke_config("phi3_mini_3_8b"), num_layers=4, branch_layers=(1, 3)
    )
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    ex = TierExecutor(cfg, params, segments_for_cuts(cfg, ()))
    tok = jax.random.randint(jax.random.PRNGKey(2), (8, 1), 0, cfg.vocab_size)
    res, _ = ex.step(tok, 0, M.init_caches(cfg, 8, 32))
    ents = np.concatenate([res.branch_entropy[l] for l in cfg.branch_layers])
    cfg = dataclasses.replace(
        cfg, exit_threshold=float((ents.min() + ents.max()) / 2)
    )
    return cfg, params


@pytest.fixture(scope="module")
def ssm_model():
    """Mamba2 smoke trunk with one side branch (SSD state scatter path)."""
    cfg = dataclasses.replace(get_smoke_config("mamba2_130m"), branch_layers=(1,))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, n, plen, seed=5):
    r = np.random.default_rng(seed)
    return [
        r.integers(0, cfg.vocab_size, size=plen).astype(np.int32)
        for _ in range(n)
    ]


def _target(cfg, plen=6, seed=9):
    return (
        np.random.default_rng(seed)
        .integers(0, cfg.vocab_size, size=plen)
        .astype(np.int32)
    )


def _server(cfg, params, cuts, *, compaction="bucketed", use_kernels=None,
            slots=4, context_len=64, **kw):
    """K=1/2/3 server over the same scheduler API."""
    if len(cuts) == 0:
        return ServingEngine(
            cfg, params, context_len=context_len, slots=slots,
            use_kernels=use_kernels,
        )
    if len(cuts) == 1:
        return PartitionedServer(
            cfg, params, cuts[0], compaction=compaction,
            use_kernels=use_kernels, slots=slots, context_len=context_len,
            **kw,
        )
    tiers = [TierSpec(f"t{j}", 1.0, 1e9) for j in range(len(cuts))]
    tiers.append(TierSpec("cloud", 1.0))
    return MultiTierServer(
        cfg, params, tiers, cuts, compaction=compaction,
        use_kernels=use_kernels, slots=slots, context_len=context_len,
    )


def _solo(cfg, params, cuts, budget=5, **kw):
    srv = _server(cfg, params, cuts, **kw)
    srv.submit(_target(cfg), budget)
    return srv.drain()[0]


def _recycled(cfg, params, cuts, budget=5, **kw):
    """Fill every slot with mixed-length/mixed-budget traffic, then submit
    the target so it lands in a recycled slot mid-flight."""
    srv = _server(cfg, params, cuts, **kw)
    for p in _prompts(cfg, 6, 4):
        srv.submit(p, 3)
    for p in _prompts(cfg, 2, 6, seed=7):
        srv.submit(p, 4)
    rid = srv.submit(_target(cfg), budget)
    srv.drain()
    res = srv.scheduler.results[rid]
    assert res.admitted_step > 0, "target must not be admitted at step 0"
    return res


def _assert_same_request(a, b):
    assert a.tokens == b.tokens
    assert a.exited == b.exited
    assert a.exit_tiers == b.exit_tiers


class TestSlotReuseBitwise:
    """The tentpole invariant: trajectory is a pure function of the
    request, independent of slot history and batch neighbors."""

    @pytest.mark.parametrize("cuts", [(), (2,), (1, 3)])
    @pytest.mark.parametrize("compaction", ["bucketed", "off"])
    def test_recycled_slot_matches_solo(self, deep_model, cuts, compaction):
        cfg, params = deep_model
        if not cuts and compaction == "off":
            pytest.skip("ServingEngine has no compaction knob")
        kw = {} if not cuts else {"compaction": compaction}
        solo = _solo(cfg, params, cuts, **kw)
        rec = _recycled(cfg, params, cuts, **kw)
        _assert_same_request(solo, rec)

    @pytest.mark.parametrize("cuts", [(2,), (1, 3)])
    def test_recycled_slot_matches_solo_with_kernels(self, deep_model, cuts):
        """use_kernels=True off-TPU runs the Pallas kernels in interpret
        mode — flash_decode's per-row q_pos scalar prefetch included."""
        cfg, params = deep_model
        solo = _solo(cfg, params, cuts, use_kernels=True)
        rec = _recycled(cfg, params, cuts, use_kernels=True)
        _assert_same_request(solo, rec)

    @pytest.mark.parametrize("use_kernels", [False, True])
    def test_ssm_recycled_slot_matches_solo(self, ssm_model, use_kernels):
        """Mamba2: the recycled slot's conv window + SSM state come from
        the row-targeted prefill scatter, not the previous occupant."""
        cfg, params = ssm_model
        solo = _solo(cfg, params, (), budget=4, use_kernels=use_kernels)
        rec = _recycled(cfg, params, (), budget=4, use_kernels=use_kernels)
        _assert_same_request(solo, rec)

    def test_mla_moe_recycled_slot_matches_solo(self):
        """MLA latent-cache rows (per-row ckv/k_rope ring writes + the
        absorbed decode's per-sequence positions) through a MoE trunk."""
        cfg = get_smoke_config("deepseek_v3_671b")
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        tgt = _target(cfg, plen=5, seed=3)

        def run(fill):
            eng = ServingEngine(cfg, params, context_len=32, slots=3)
            if fill:
                for p in _prompts(cfg, 4, 3, seed=1):
                    eng.submit(p, 2)
            rid = eng.submit(tgt, 4)
            eng.drain()
            res = eng.scheduler.results[rid]
            if fill:
                assert res.admitted_step > 0
            return res

        _assert_same_request(run(False), run(True))

    def test_trajectory_independent_of_neighbors(self, deep_model):
        """Same slot, different co-resident traffic -> same trajectory."""
        cfg, params = deep_model
        a = _recycled(cfg, params, (2,))
        srv = _server(cfg, params, (2,))
        for p in _prompts(cfg, 8, 3, seed=11):
            srv.submit(p, 2)
        rid = srv.submit(_target(cfg), 5)
        srv.drain()
        b = srv.scheduler.results[rid]
        _assert_same_request(a, b)


class TestRowTargetedPrefill:
    def test_prefill_rows_matches_solo_prefill(self, deep_model):
        """Every cache leaf of a recycled row equals a fresh solo prefill:
        prompt slots written, stale tail slots reset to empty."""
        cfg, params = deep_model
        ex = TierExecutor(cfg, params, segments_for_cuts(cfg, ()))
        caches = M.init_caches(cfg, 4, 32)
        # Dirty every row first (simulate previous occupants).
        tok = jax.random.randint(jax.random.PRNGKey(1), (4, 1), 0, cfg.vocab_size)
        for i in range(3):
            res, caches = ex.step(tok, np.full(4, i, np.int32), caches)
            tok = res.tokens_dev[:, None]
        prompts = np.stack(_prompts(cfg, 2, 7))
        caches, tok0 = ex.prefill_rows(caches, prompts, np.array([2, 0]))
        solo = M.init_caches(cfg, 2, 32)
        logits, solo = jax.jit(
            lambda p, i, c: M.prefill(p, i, cfg, c)
        )(params, {"tokens": prompts}, solo)
        np.testing.assert_array_equal(
            np.asarray(tok0),
            np.asarray(jax.numpy.argmax(logits[:, 0], -1)),
        )
        got = np.asarray(caches["blocks"]["self"]["k"])[:, [2, 0]]
        np.testing.assert_array_equal(got, np.asarray(solo["blocks"]["self"]["k"]))
        got_pos = np.asarray(caches["blocks"]["self"]["pos"])[:, [2, 0]]
        np.testing.assert_array_equal(
            got_pos, np.asarray(solo["blocks"]["self"]["pos"])
        )
        # Rows 1 and 3 were not touched by the admission.
        assert (np.asarray(caches["blocks"]["self"]["pos"])[:, [1, 3]] >= 0).any()

    def test_reset_rows_invalidates_slots(self, deep_model):
        cfg, params = deep_model
        ex = TierExecutor(cfg, params, segments_for_cuts(cfg, ()))
        caches = M.init_caches(cfg, 3, 16)
        res, caches = ex.step(
            jax.random.randint(jax.random.PRNGKey(1), (3, 1), 0, cfg.vocab_size),
            np.zeros(3, np.int32), caches,
        )
        caches = ex.reset_rows(caches, np.array([1]))
        pos = np.asarray(caches["blocks"]["self"]["pos"])
        assert (pos[:, 1] == -1).all()
        assert (pos[:, 0] == 0).any() and (pos[:, 2] == 0).any()


class TestSchedulerMechanics:
    def test_one_sync_per_decode_step(self, deep_model):
        """Admission prefill and retirement bookkeeping add no syncs: the
        request loop fetches exactly once per decode step (+ counted
        overflow retries)."""
        cfg, params = deep_model
        srv = _server(cfg, params, (2,), slots=4)
        for p in _prompts(cfg, 7, 4):
            srv.submit(p, 3)
        ex = srv.executor
        syncs0, retries0 = ex.host_syncs, ex.overflow_retries
        reports = srv.run()
        steps = len(reports)
        assert steps > 0
        assert ex.host_syncs - syncs0 == steps + (
            ex.overflow_retries - retries0
        )

    def test_ttft_and_latency_accounting(self, deep_model):
        cfg, params = deep_model
        srv = _server(cfg, params, (2,), slots=2)
        rids = [srv.submit(p, 3) for p in _prompts(cfg, 4, 4)]
        done = srv.drain()
        assert len(done) == 4
        for rid in rids:
            r = srv.scheduler.results[rid]
            assert r.done and len(r.tokens) == 3
            assert r.ttft_s is not None and r.latency_s is not None
            assert 0 < r.ttft_s <= r.latency_s
        # Queued-behind requests waited longer to first token.
        assert (
            srv.scheduler.results[rids[-1]].ttft_s
            >= srv.scheduler.results[rids[0]].ttft_s
        )

    def test_stop_on_exit_retires_at_first_branch_exit(self, deep_model):
        cfg, params = deep_model
        # Threshold above every entropy -> every token exits at branch 1.
        cfg_all = dataclasses.replace(cfg, exit_threshold=1.5)
        srv = _server(cfg_all, params, (2,), slots=2)
        rid = srv.submit(_target(cfg_all), 10, stop_on_exit=True)
        done = srv.drain()
        r = srv.scheduler.results[rid]
        assert r.done and len(r.tokens) == 1 and r.exited == [True]

    def test_gang_policy_is_lockstep_and_slower(self, deep_model):
        """gang admission (the lock-step degenerate case) pins freed slots
        until the whole wave drains; continuous admission finishes the
        same mixed-budget workload in fewer decode steps."""
        cfg, params = deep_model
        cfg = dataclasses.replace(cfg, exit_threshold=0.0)  # no early exits

        def run(policy):
            srv = _server(cfg, params, (2,), slots=4)
            sched = RequestScheduler(srv, 4, 64, policy=policy)
            for i, p in enumerate(_prompts(cfg, 8, 4)):
                sched.submit(p, 2 if i % 2 else 8)
            sched.run()
            assert len(sched.finished) == 8
            assert sched.total_tokens == 4 * (2 + 8)
            return sched.step_count

        gang_steps = run("gang")
        cont_steps = run("continuous")
        assert gang_steps == 16  # two full waves of max(budget) steps
        assert cont_steps < gang_steps

    def test_arrival_step_gates_admission(self, deep_model):
        cfg, params = deep_model
        srv = _server(cfg, params, (2,), slots=2)
        rid = srv.submit(_target(cfg), 2, arrival_step=3)
        srv.drain()
        assert srv.scheduler.results[rid].admitted_step >= 3

    def test_result_active_mask_is_a_snapshot(self, deep_model):
        """TierStepResult.active must not alias the caller's mask: the
        scheduler clears retiring slots before on_step callbacks (the
        controller) read the result."""
        cfg, params = deep_model
        ex = TierExecutor(cfg, params, segments_for_cuts(cfg, (2,)))
        caches = M.init_caches(cfg, 4, 16)
        active = np.array([True, True, False, True])
        res, _ = ex.step(
            jax.random.randint(jax.random.PRNGKey(1), (4, 1), 0, cfg.vocab_size),
            np.zeros(4, np.int32), caches, active=active,
        )
        active[0] = False  # retirement mutates the scheduler's mask
        assert res.active[0]  # ...but the step's snapshot is unchanged

    def test_future_arrival_does_not_block_arrived_requests(self, deep_model):
        """Admission is FIFO among *arrived* requests: a queue head whose
        simulated arrival is far out never head-of-line-blocks a later
        submit that is already admissible."""
        cfg, params = deep_model
        srv = _server(cfg, params, (2,), slots=2)
        late = srv.submit(_target(cfg, seed=1), 2, arrival_step=50)
        early = srv.submit(_target(cfg, seed=2), 2)
        srv.drain()
        res = srv.scheduler.results
        assert res[early].admitted_step == 0
        assert res[late].admitted_step >= 50
        # TTFT of the simulated late arrival is measured from its
        # arrival, not from submit(): it can't exceed the early request's
        # whole wall-clock span plus its own serving time.
        assert res[late].ttft_s < res[late].latency_s + res[early].latency_s

    def test_submit_validates_budget(self, deep_model):
        cfg, params = deep_model
        srv = _server(cfg, params, (2,), slots=2, context_len=16)
        with pytest.raises(ValueError, match="context_len"):
            srv.submit(_target(cfg, plen=10), 10)
        with pytest.raises(ValueError, match="max_new_tokens"):
            srv.submit(_target(cfg, plen=4), 0)


class TestBucketHintWave:
    def test_hints_track_mass_retirement_and_readmission(self, deep_model):
        """After a retirement wave the downstream bucket shrinks to the
        live width; a re-admission wave overflows once (counted, bitwise
        safe) and the bucket recovers to the full slot count."""
        cfg, params = deep_model
        cfg = dataclasses.replace(cfg, exit_threshold=0.0)  # survivors = live
        srv = PartitionedServer(
            cfg, params, 2, slots=8, context_len=64, hint_window=1
        )
        sched = srv.scheduler
        for i in range(8):
            sched.submit(_target(cfg, seed=i), 3 if i < 4 else 9)
        buckets = []
        retries = []
        while sched.active.any() or sched.queue:
            rep = sched.step()
            if rep is None:
                continue
            res = rep.server_report.tier_result
            buckets.append(res.compaction[0].bucket if res.compaction else 0)
            retries.append(srv.executor.overflow_retries)
            if rep.step == 6:
                # Re-admission wave into the 4 freed slots.
                for j in range(4):
                    sched.submit(_target(cfg, seed=20 + j), 3)
        # Full occupancy first: the cloud tier ran the full batch.
        assert buckets[0] == 8
        # After the short-budget half retired, the hint shrank the bucket
        # to the live width...
        assert bucket_for(4, 8) in buckets[3:6]
        # ...and the re-admission wave grew it back (through a counted
        # overflow retry, never a wrong answer).
        assert buckets[-1] == 8
        assert retries[-1] >= 1


class TestOccupancyCost:
    def test_occupancy_one_is_identity(self):
        t_c = np.array([0.0, 0.01, 0.01, 0.01, 0.01])
        alpha = np.full(5, 64e3)
        p = np.zeros(5)
        tiers = [TierSpec("e", 4.0, 1e6), TierSpec("c", 1.0)]
        for cut in range(5):
            a = expected_time_multitier(t_c, alpha, p, tiers, (cut,), batch=8)
            b = expected_time_multitier(
                t_c, alpha, p, tiers, (cut,), batch=8, occupancy=1.0
            )
            assert a == b

    def test_low_occupancy_shrinks_downstream_and_transfer(self):
        t_c = np.array([0.0, 0.01, 0.01, 0.01, 0.01])
        alpha = np.full(5, 64e3)
        p = np.zeros(5)
        tiers = [TierSpec("e", 4.0, 1e6), TierSpec("c", 1.0)]
        full = expected_time_multitier(t_c, alpha, p, tiers, (2,), batch=8)
        quarter = expected_time_multitier(
            t_c, alpha, p, tiers, (2,), batch=8, occupancy=0.25
        )
        assert quarter < full
        # Edge-only plans ship nothing downstream: occupancy can't help.
        edge_full = expected_time_multitier(t_c, alpha, p, tiers, (4,), batch=8)
        edge_q = expected_time_multitier(
            t_c, alpha, p, tiers, (4,), batch=8, occupancy=0.25
        )
        assert edge_q == edge_full

    def test_occupancy_validation(self):
        t_c = np.array([0.0, 0.01])
        tiers = [TierSpec("e", 1.0, 1e6), TierSpec("c", 1.0)]
        with pytest.raises(ValueError, match="batch"):
            expected_time_multitier(
                t_c, np.zeros(2), np.zeros(2), tiers, (1,), occupancy=0.5
            )
        with pytest.raises(ValueError, match="occupancy"):
            expected_time_multitier(
                t_c, np.zeros(2), np.zeros(2), tiers, (1,), batch=4,
                occupancy=1.5,
            )

    def test_occupancy_moves_the_solved_cut(self):
        """A fat downstream tier is worth paying for at full occupancy but
        not at low occupancy (the entry tier still computes the nominal
        batch, the downstream tier only the live survivors)."""
        n = 4
        t_c = np.concatenate([[0.0], np.full(n, 0.01)])
        alpha = np.full(n + 1, 1e3)
        p = np.zeros(n + 1)
        tiers = [TierSpec("edge", 2.0, 1e9), TierSpec("cloud", 1.0)]
        plan_full = solve_multitier(t_c, alpha, p, tiers, batch=8)
        plan_low = solve_multitier(
            t_c, alpha, p, tiers, batch=8, occupancy=1.0 / 8.0
        )
        # Full occupancy: ship everything at layer 0 (cloud is 2x faster
        # per row and rows are everything).  1/8 occupancy: the bucketed
        # cloud still computes 1 row while the edge always pays the full
        # batch — the cut must not move backward, and costs drop.
        assert plan_low.expected_time_s <= plan_full.expected_time_s
        assert plan_low.cut_after >= plan_full.cut_after

    def test_estimator_prices_live_width(self, deep_model):
        """PartitionedServer.est_latency_s under continuous batching uses
        the step's live width: a half-occupied batch reports a cheaper
        (never costlier) step than the same batch fully occupied."""
        cfg, params = deep_model
        cfg = dataclasses.replace(cfg, exit_threshold=0.0)
        costs = [LayerCost(f"l{i}", 0, 0, cfg.d_model * 2.0, 1e-3)
                 for i in range(cfg.num_layers)]
        profile = build_cost_profile(
            costs, cfg.branch_layers, np.zeros(2), "3g", 50.0, 64.0
        )
        srv = PartitionedServer(
            cfg, params, 2, cost_profile=profile, slots=4, context_len=64
        )
        sched = srv.scheduler
        for p in _prompts(cfg, 2, 4):
            sched.submit(p, 6)
        half = sched.step().server_report
        assert half.live == 2
        for p in _prompts(cfg, 2, 4, seed=8):
            sched.submit(p, 6)
        full = sched.step().server_report
        assert full.live == 4
        assert half.est_latency_s <= full.est_latency_s

    def test_controller_tracks_occupancy(self, deep_model):
        """observe() feeds the live width into a decaying estimate that
        batched solves consume (explicit occupancy= overrides it)."""
        cfg, params = deep_model
        srv = PartitionedServer(cfg, params, 2, slots=4, context_len=64)
        costs = [LayerCost(f"l{i}", 0, 0, cfg.d_model * 2.0, 1e-3)
                 for i in range(cfg.num_layers)]
        profile = build_cost_profile(
            costs, cfg.branch_layers, np.array([0.2, 0.2]), "3g", 50.0, 64.0
        )
        ctrl = RepartitionController(srv, profile, batch=4)
        sched = RequestScheduler(srv, 4, 64, on_step=[ctrl.observe])
        sched.submit(_target(cfg), 4)
        sched.run()
        assert ctrl._occ_est is not None
        assert 0 < ctrl._occ_est <= 0.5  # one live slot of four, decayed
        ctrl.occupancy = 0.75
        assert ctrl._solve_occupancy() == 0.75


class TestSampledProbes:
    def test_probe_mask_covers_sampled_rows_only(self, deep_model):
        """probe_sample_frac=0.5 evaluates the discarded branch's head on
        half the batch, reports the coverage mask, and never touches the
        trajectory."""
        cfg, params = deep_model
        ex = TierExecutor(cfg, params, segments_for_cuts(cfg, (2,)))
        exf = TierExecutor(cfg, params, segments_for_cuts(cfg, (2,)))
        caches = M.init_caches(cfg, 8, 32)
        cachesf = M.init_caches(cfg, 8, 32)
        tok = jax.random.randint(jax.random.PRNGKey(2), (8, 1), 0, cfg.vocab_size)
        ex.probe_next = True
        ex.probe_sample_frac = 0.5
        exf.probe_next = True  # full probe reference
        res, caches = ex.step(tok, 0, caches)
        resf, cachesf = exf.step(tok, 0, cachesf)
        np.testing.assert_array_equal(res.tokens, resf.tokens)
        np.testing.assert_array_equal(res.exited, resf.exited)
        # Branch 3 is discarded by the split-2 plan -> probed, sampled.
        cover = res.branch_probe_mask[3]
        assert cover.sum() == 4
        # Covered rows agree with the full probe; uncovered read False.
        np.testing.assert_array_equal(
            res.branch_take[3][cover], resf.branch_take[3][cover]
        )
        assert not res.branch_take[3][~cover].any()

    def test_probe_rotation_cycles_the_batch(self, deep_model):
        """Uncompacted tiers sample batch rows directly: the rotation
        cursor cycles every row across successive probes.  (Compacted
        tiers sample the dense sub-batch — the survivor permutation lives
        on device — so coverage there follows compaction order and is
        asserted via the reported mask, not a fixed rotation.)"""
        cfg, params = deep_model
        ex = TierExecutor(
            cfg, params, segments_for_cuts(cfg, (2,)), compaction="off"
        )
        caches = M.init_caches(cfg, 8, 32)
        tok = jax.random.randint(jax.random.PRNGKey(2), (8, 1), 0, cfg.vocab_size)
        ex.probe_sample_frac = 0.25
        seen = np.zeros(8, bool)
        for i in range(4):
            ex.probe_next = True
            res, caches = ex.step(tok, i, caches)
            seen |= res.branch_probe_mask[3]
            tok = res.tokens_dev[:, None]
        assert seen.all()  # 4 probes x 2 rows rotate over all 8 rows

    def test_controller_sampled_probe_accounting(self, deep_model):
        """Arrivals at a sampled probed branch count covered rows only, so
        the conditional estimate stays a valid probability."""
        cfg, params = deep_model
        srv = PartitionedServer(cfg, params, 2, slots=8)
        costs = [LayerCost(f"l{i}", 0, 0, cfg.d_model * 2.0, 1e-3)
                 for i in range(cfg.num_layers)]
        profile = build_cost_profile(
            costs, cfg.branch_layers, np.array([0.2, 0.2]), "3g", 50.0, 64.0
        )
        ctrl = RepartitionController(
            srv, profile, explore_every_n=2, probe_sample_frac=0.5
        )
        caches = M.init_caches(cfg, 8, 32)
        tok = jax.random.randint(jax.random.PRNGKey(2), (8, 1), 0, cfg.vocab_size)
        covered = 0
        for i in range(6):
            rep, caches = srv.step(tok, i, caches)
            res = rep.tier_result
            if 3 in res.branch_probe_mask:
                covered += int(res.branch_probe_mask[3].sum())
            ctrl.observe(rep.tier_result)
            tok = res.tokens_dev[:, None]
        assert covered > 0
        j3 = list(cfg.branch_layers).index(3)
        assert ctrl._arrivals[j3] <= covered  # never counts uncovered rows
        probs = ctrl.measured_probs()
        assert (probs >= 0).all() and (probs <= 1).all()

    def test_probe_sample_frac_validation(self, deep_model):
        cfg, params = deep_model
        srv = PartitionedServer(cfg, params, 2)
        costs = [LayerCost(f"l{i}", 0, 0, cfg.d_model * 2.0, 1e-3)
                 for i in range(cfg.num_layers)]
        profile = build_cost_profile(
            costs, cfg.branch_layers, np.zeros(2), "3g", 50.0, 64.0
        )
        with pytest.raises(ValueError, match="probe_sample_frac"):
            RepartitionController(srv, profile, probe_sample_frac=0.0)


class TestKernelAwareProfiler:
    def test_profile_decode_layers_analyze(self, deep_model):
        """Both lowerings produce one cost per trunk layer with the
        residual stream as alpha; the kernel path runs in interpret mode
        off-TPU."""
        cfg, params = deep_model
        for kernels in (False, True):
            costs = profile_decode_layers(
                cfg, params, batch=2, context_len=16, use_kernels=kernels
            )
            assert len(costs) == cfg.num_layers
            for c in costs:
                assert np.isfinite(c.time_s) and c.time_s >= 0
                # alpha_i = the (B, 1, d) bf16 residual stream.
                assert c.output_bytes == 2 * 1 * cfg.d_model * 2.0

    def test_profile_decode_layers_measure(self, deep_model):
        cfg, params = deep_model
        costs = profile_decode_layers(
            cfg, params, batch=2, context_len=16,
            use_kernels=True, mode="measure", iters=2, warmup=1,
        )
        assert len(costs) == cfg.num_layers
        assert all(c.time_s > 0 for c in costs)

    def test_profile_mode_validation(self, deep_model):
        cfg, params = deep_model
        with pytest.raises(ValueError, match="mode"):
            profile_decode_layers(cfg, params, 2, 16, mode="wat")
