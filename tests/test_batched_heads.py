"""Batched exit-head evaluation (serving/tiers.py "Batched exit heads").

The runtime's batched path — one stacked (K, B, D) branch-norm +
projection against the shared unembedding and one multi-head fused
entropy-exit decision — must be *bitwise* interchangeable with the
historical sequential per-head loop, because the exit decision drives
control flow (who ships, who finalizes): tokens, exit masks, per-branch
first-exit ``branch_take``, ``branch_entropy``, sampled-probe coverage
and degraded-mode forced finalization all have to match exactly.

Covered here:

  * the multi-head kernel (``entropy_exit_argmax_heads``) vs the jnp
    oracle and, per head, bitwise vs the single-head kernel;
  * stacked projection vs per-head projection (bitwise logits);
  * end-to-end decode parity across K in {1, 2, 3} heads x compaction
    on/off x use_kernels (interpret) x GQA + Mamba2 trunks, with the
    one-host-sync-per-step invariant on both paths;
  * probe-step parity (all-heads probes and sampled ``probe_m`` probes);
  * degraded-step parity (forced finalization off the fallback head);
  * the cost layer: ``branch_head_cost``'s batched-vs-sequential pricing
    and the ``head_cost`` term in ``expected_time_multitier`` /
    ``solve_multitier`` / both servers' ``est_latency_s``.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import LayerCost, build_cost_profile
from repro.core.multitier import TierSpec, expected_time_multitier, solve_multitier
from repro.core.profiler import branch_head_cost
from repro.kernels import ops, ref
from repro.models import model as M
from repro.serving import TierExecutor, segments_for_cuts
from repro.serving.faults import FlapWindow, HopPolicy, LinkFaultModel
from repro.serving.partitioned import PartitionedServer

B = 8
BRANCHES = {1: (1,), 2: (1, 3), 3: (1, 2, 3)}


def _toks(cfg, batch=B, seed=2):
    return jax.random.randint(
        jax.random.PRNGKey(seed), (batch, 1), 0, cfg.vocab_size
    )


def _calibrated(cfg, params):
    """Set exit_threshold to the mixed-exit midpoint of step-0 entropies."""
    ex = TierExecutor(cfg, params, segments_for_cuts(cfg, ()))
    res, _ = ex.step(_toks(cfg), 0, M.init_caches(cfg, B, 32))
    ents = np.concatenate([res.branch_entropy[l] for l in cfg.branch_layers])
    return dataclasses.replace(
        cfg, exit_threshold=float((ents.min() + ents.max()) / 2)
    )


@pytest.fixture(scope="module")
def gqa_model():
    cfg = dataclasses.replace(
        get_smoke_config("phi3_mini_3_8b"), num_layers=4,
        branch_layers=(1, 2, 3),
    )
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return _calibrated(cfg, params), params


@pytest.fixture(scope="module")
def ssm_model():
    cfg = dataclasses.replace(
        get_smoke_config("mamba2_130m"), num_layers=4, branch_layers=(1, 2, 3)
    )
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return _calibrated(cfg, params), params


def _run(cfg, params, cuts, *, batched, steps=3, compaction="bucketed",
         use_kernels=None, probe=None, probe_frac=None, **kw):
    ex = TierExecutor(
        cfg, params, segments_for_cuts(cfg, cuts, **(
            dict(uplinks=(1e9,) * len(cuts)) if kw.get("fault_model")
            else {}
        )),
        compaction=compaction, use_kernels=use_kernels,
        batched_heads=batched, **kw,
    )
    if probe_frac is not None:
        ex.probe_sample_frac = probe_frac
    caches = M.init_caches(cfg, B, 32)
    tok = _toks(cfg)
    hist = []
    for i in range(steps):
        if probe == "all" or (probe == "alternate" and i % 2):
            ex.probe_next = True
        res, caches = ex.step(tok, i, caches)
        hist.append(res)
        tok = res.tokens_dev[:, None]
    # The batched path must not cost extra syncs: one per decode step
    # (plus bucket-overflow re-runs), same as the sequential baseline.
    assert ex.host_syncs == steps + ex.overflow_retries
    return ex, hist


def _assert_same(hist_a, hist_b, *, entropy=True):
    for a, b in zip(hist_a, hist_b):
        np.testing.assert_array_equal(a.tokens, b.tokens)
        np.testing.assert_array_equal(a.exited, b.exited)
        np.testing.assert_array_equal(a.exit_tier, b.exit_tier)
        assert a.shipped_per_hop == b.shipped_per_hop
        assert sorted(a.branch_take) == sorted(b.branch_take)
        for layer in a.branch_take:
            np.testing.assert_array_equal(
                a.branch_take[layer], b.branch_take[layer]
            )
        if entropy:
            assert sorted(a.branch_entropy) == sorted(b.branch_entropy)
            for layer in a.branch_entropy:
                # Entropies come out of the projection, and XLA may tile
                # the stacked (K*B, D) x (D, V) GEMM differently from the
                # per-head (B, D) x (D, V) one (observed only under the
                # 8-virtual-device CI lane), so the float diagnostic is
                # held to a few ULP rather than bitwise.  The *decisions*
                # (tokens, exit masks, takes) above stay exact.
                np.testing.assert_allclose(
                    a.branch_entropy[layer], b.branch_entropy[layer],
                    rtol=3e-7, atol=0,
                )
        for layer in getattr(a, "branch_probe_mask", {}) or {}:
            np.testing.assert_array_equal(
                a.branch_probe_mask[layer], b.branch_probe_mask[layer]
            )


# ---------------------------------------------------------------- kernel
class TestMultiHeadKernel:
    @pytest.mark.parametrize("k", [1, 2, 3])
    def test_matches_oracle_and_single_head(self, k):
        key = jax.random.PRNGKey(k)
        logits = jax.random.normal(key, (k, 5, 3000), jnp.float32) * 4
        th = jnp.linspace(0.3, 0.7, k)
        e, flag, tok = ops.entropy_exit_argmax_heads(logits, th, interpret=True)
        re_, rf, rt = ref.entropy_exit_argmax_heads_ref(logits, th)
        np.testing.assert_allclose(np.asarray(e), np.asarray(re_),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(flag), np.asarray(rf))
        np.testing.assert_array_equal(np.asarray(tok), np.asarray(rt))
        # Per-head slices bitwise match the single-head kernel: the
        # multi-head grid adds a K dimension, not a different dataflow.
        for j in range(k):
            ej, fj, tj = ops.entropy_exit_argmax(
                logits[j], float(th[j]), interpret=True
            )
            np.testing.assert_array_equal(np.asarray(e[j]), np.asarray(ej))
            np.testing.assert_array_equal(np.asarray(flag[j]), np.asarray(fj))
            np.testing.assert_array_equal(np.asarray(tok[j]), np.asarray(tj))

    def test_scalar_threshold_broadcasts(self):
        logits = jax.random.normal(jax.random.PRNGKey(0), (3, 4, 600)) * 4
        a = ops.entropy_exit_argmax_heads(logits, 0.5, interpret=True)
        b = ops.entropy_exit_argmax_heads(
            logits, jnp.full((3,), 0.5), interpret=True
        )
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_ragged_vocab_padding(self):
        # A vocab that is not a multiple of the V block: NEG_INF padding
        # must not perturb entropy or argmax.
        logits = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 2500)) * 4
        e, flag, tok = ops.entropy_exit_argmax_heads(
            logits, 0.5, interpret=True
        )
        re_, rf, rt = ref.entropy_exit_argmax_heads_ref(logits, 0.5)
        np.testing.assert_allclose(np.asarray(e), np.asarray(re_),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(tok), np.asarray(rt))


# ------------------------------------------------------------ projection
class TestStackedProjection:
    def test_stacked_logits_bitwise_match_per_head(self, gqa_model):
        cfg, params = gqa_model
        collected = {
            l: jax.random.normal(
                jax.random.PRNGKey(l), (B, 1, cfg.d_model), jnp.bfloat16
            )
            for l in cfg.branch_layers
        }
        layers, lg = jax.jit(
            lambda p, c: M.branch_logits_stacked(p, c, cfg)
        )(params, collected)
        per = jax.jit(
            lambda p, c: M.branch_logits_per_head(p, c, cfg)
        )(params, collected)
        assert tuple(layers) == cfg.branch_layers
        for r, l in enumerate(cfg.branch_layers):
            np.testing.assert_array_equal(np.asarray(lg[r]), np.asarray(per[l]))

    def test_subset_and_empty(self, gqa_model):
        cfg, params = gqa_model
        collected = {
            3: jnp.zeros((B, 1, cfg.d_model), jnp.bfloat16)
        }
        layers, lg = M.branch_logits_stacked(params, collected, cfg)
        assert layers == (3,) and lg.shape[0] == 1
        layers, lg = M.branch_logits_stacked(params, {}, cfg)
        assert layers == () and lg is None


# ------------------------------------------------------------ end to end
class TestBatchedSequentialParity:
    @pytest.mark.parametrize("k", [1, 2, 3])
    @pytest.mark.parametrize("compaction", ["bucketed", "off"])
    @pytest.mark.parametrize("use_kernels", [None, True])
    def test_gqa_matrix(self, gqa_model, k, compaction, use_kernels):
        cfg, params = gqa_model
        cfg = dataclasses.replace(cfg, branch_layers=BRANCHES[k])
        runs = [
            _run(cfg, params, (2,), batched=b, compaction=compaction,
                 use_kernels=use_kernels)[1]
            for b in (True, False)
        ]
        _assert_same(*runs)

    @pytest.mark.parametrize("use_kernels", [None, True])
    def test_mamba2(self, ssm_model, use_kernels):
        cfg, params = ssm_model
        runs = [
            _run(cfg, params, (2,), batched=b, use_kernels=use_kernels)[1]
            for b in (True, False)
        ]
        _assert_same(*runs)

    def test_single_tier_all_heads(self, gqa_model):
        cfg, params = gqa_model
        runs = [_run(cfg, params, (), batched=b)[1] for b in (True, False)]
        _assert_same(*runs)


class TestProbeParity:
    def test_all_heads_probe_steps(self, gqa_model):
        cfg, params = gqa_model
        runs = [
            _run(cfg, params, (2,), batched=b, probe="alternate", steps=4)[1]
            for b in (True, False)
        ]
        _assert_same(*runs)

    def test_sampled_probes(self, gqa_model):
        cfg, params = gqa_model
        runs = [
            _run(cfg, params, (2,), batched=b, probe="all", probe_frac=0.5,
                 steps=4)[1]
            for b in (True, False)
        ]
        _assert_same(*runs)


class TestDegradedParity:
    def test_forced_finalization(self, gqa_model):
        """Hop kill mid-run: the degraded steps' forced tokens come off
        the fallback head's argmax — identical on both head paths."""
        cfg, params = gqa_model
        fm = LinkFaultModel(
            seed=0, flaps=(FlapWindow(hop=0, start_step=2, end_step=10_000),)
        )
        hp = HopPolicy(timeout_s=0.01, max_retries=1, backoff_s=0.001,
                       breaker_threshold=2, breaker_cooldown_steps=3)
        hists = []
        for b in (True, False):
            _, hist = _run(
                cfg, params, (2,), batched=b, steps=5,
                fault_model=LinkFaultModel(seed=0, flaps=fm.flaps),
                hop_policy=hp, simulate_network=True,
            )
            hists.append(hist)
        _assert_same(*hists)
        for a, c in zip(*hists):
            if a.degraded is not None:
                np.testing.assert_array_equal(a.degraded, c.degraded)
        assert any(
            h.degraded is not None and h.degraded.any() for h in hists[0]
        )


# ------------------------------------------------------------ cost layer
class TestHeadCostPricing:
    def test_batched_amortizes_weight_read(self, gqa_model):
        cfg, _ = gqa_model
        hb = branch_head_cost(cfg, B, heads_batched=True)
        hs = branch_head_cost(cfg, B, heads_batched=False)
        assert hb(0) == hs(0) == 0.0
        assert hb(1) == pytest.approx(hs(1))
        for m in (2, 3, 5):
            assert hb(m) < hs(m)
            assert hs(m) == pytest.approx(m * hs(1))

    def test_expected_time_head_term(self):
        n = 6
        t_c = np.array([0.0] + [1e-3] * n)
        alpha = np.array([0.0] + [1e5] * n)
        p = np.zeros(n + 1)
        p[1] = p[2] = p[3] = 0.2
        tiers = [TierSpec("edge", 4.0, 1e9), TierSpec("cloud", 1.0)]
        cfg = get_smoke_config("phi3_mini_3_8b")
        hb = branch_head_cost(cfg, B, heads_batched=True)
        hs = branch_head_cost(cfg, B, heads_batched=False)
        base = expected_time_multitier(t_c, alpha, p, tiers, (5,))
        wb = expected_time_multitier(
            t_c, alpha, p, tiers, (5,), head_cost=hb, branch_layers=(1, 2, 3)
        )
        ws = expected_time_multitier(
            t_c, alpha, p, tiers, (5,), head_cost=hs, branch_layers=(1, 2, 3)
        )
        assert ws > wb > base
        # Default branch_layers = the nonzero-probability layers.
        assert expected_time_multitier(
            t_c, alpha, p, tiers, (5,), head_cost=hb
        ) == pytest.approx(wb)
        # Bucketed-runtime weighting prices the joint head_cost(m) once.
        wb2 = expected_time_multitier(
            t_c, alpha, p, tiers, (5,), batch=B, head_cost=hb,
            branch_layers=(1, 2, 3),
        )
        ws2 = expected_time_multitier(
            t_c, alpha, p, tiers, (5,), batch=B, head_cost=hs,
            branch_layers=(1, 2, 3),
        )
        assert ws2 > wb2
        # A branch sitting exactly at a cut is discarded by the runtime,
        # so the estimator must not price it: only layer-1/2 heads remain.
        at_cut = expected_time_multitier(
            t_c, alpha, p, tiers, (3,), head_cost=hs, branch_layers=(1, 2, 3)
        )
        two_heads = expected_time_multitier(
            t_c, alpha, p, tiers, (3,), head_cost=hs, branch_layers=(1, 2)
        )
        assert at_cut == pytest.approx(two_heads)

    def test_solver_accepts_head_cost(self):
        n = 6
        t_c = np.array([0.0] + [1e-3] * n)
        alpha = np.array([0.0] + [1e5] * n)
        p = np.zeros(n + 1)
        p[2] = 0.4
        tiers = [TierSpec("edge", 2.0, 1e6), TierSpec("cloud", 1.0)]
        cfg = get_smoke_config("phi3_mini_3_8b")
        hs = branch_head_cost(cfg, 64, heads_batched=False)
        plan0 = solve_multitier(t_c, alpha, p, tiers)
        plan = solve_multitier(
            t_c, alpha, p, tiers, head_cost=hs, branch_layers=(2,)
        )
        assert len(plan.cut_after) == 1
        # The head term can only make a priced plan costlier than the
        # head-free optimum priced without it.
        assert plan.expected_time_s >= plan0.expected_time_s

    def test_server_estimate_prices_heads(self, gqa_model):
        cfg, params = gqa_model
        costs = [LayerCost(f"l{i}", 0, 0, cfg.d_model * 2.0, 1e-3)
                 for i in range(cfg.num_layers)]
        prof = build_cost_profile(
            costs, cfg.branch_layers, np.array([0.2, 0.2, 0.2]), "3g",
            50.0, 64.0,
        )
        ests = {}
        for price, batched in [(False, True), (True, True), (True, False)]:
            srv = PartitionedServer(
                cfg, params, 3, cost_profile=prof,
                heads_batched=batched, price_heads=price,
            )
            rep, _ = srv.step(_toks(cfg), 0, M.init_caches(cfg, B, 32))
            ests[(price, batched)] = rep.est_latency_s
        assert (ests[(True, False)] > ests[(True, True)]
                > ests[(False, True)])
