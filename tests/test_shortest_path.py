"""Solver equivalence: Dijkstra on G'_BDNN == closed form == brute force.

This is the paper's central claim (Sec. V): BranchyNet partitioning reduces
to shortest path.  We verify it exhaustively and property-based.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.core import (
    BranchSpec,
    CostProfile,
    NetworkProfile,
    Partitioner,
    brute_force_split,
    build_partition_graph,
    chain_costs_jax,
    dijkstra,
    expected_time,
    expected_time_all_splits,
    shortest_path_plan,
    solve_chain_jax,
)

import jax.numpy as jnp


def make_profile(
    t_c, alpha, branch_pos, probs, gamma=10.0, bw=5.85e6, include_bc=False, bc=None
):
    branches = tuple(
        BranchSpec(p, q, compute_time_cloud=(bc[i] if bc else 0.0))
        for i, (p, q) in enumerate(zip(branch_pos, probs))
    )
    return CostProfile(
        t_c=np.concatenate([[0.0], np.asarray(t_c, float)]),
        alpha=np.asarray(alpha, float),
        branches=branches,
        gamma=gamma,
        network=NetworkProfile("test", bw),
        include_branch_compute=include_bc,
    )


class TestClosedForm:
    def test_no_branch_matches_eq3(self):
        """With no branches, E[T(s)] must equal Eq. 3: T_e + t_net + T_c."""
        t_c = [0.01, 0.02, 0.03, 0.04]
        alpha = [1e6, 2e5, 5e4, 1e5, 4e3]
        prof = make_profile(t_c, alpha, [], [], gamma=10.0, bw=1e7)
        costs = expected_time_all_splits(prof)
        for s in range(5):
            t_e = 10.0 * sum(t_c[:s])
            t_net = alpha[s] * 8 / 1e7 if s < 4 else 0.0
            tc = sum(t_c[s:])
            assert costs[s] == pytest.approx(t_e + t_net + tc)

    def test_single_branch_matches_eq5(self):
        """Paper Eq. 5, one branch at k=1, split s >= k."""
        t_c = np.array([0.02, 0.05, 0.04])
        alpha = np.array([6e5, 1e5, 3e4, 1e3])
        p = 0.7
        prof = make_profile(t_c, alpha, [1], [p], gamma=100.0, bw=5.85e6)
        costs = expected_time_all_splits(prof)
        # Split at s=2 (branch b_1 evaluated on edge).
        s = 2
        t_e = prof.t_e
        lhs = costs[s]
        # Eq. 5: sum_{i<=k} t_i^e + (1 - p_Y(1)) (sum_{k<i<=s} t_i^e + t_net + T_c)
        rhs = t_e[1] + (1 - p) * (t_e[2] + alpha[2] * 8 / 5.85e6 + t_c[2])
        assert lhs == pytest.approx(rhs)

    def test_p_one_kills_downstream_cost(self):
        """p == 1: costs after the branch vanish (paper Sec. IV-C extreme)."""
        prof = make_profile(
            [0.01, 0.9, 0.9], [1e6, 1e4, 1e4, 1e3], [1], [1.0], gamma=1.0, bw=1e6
        )
        costs = expected_time_all_splits(prof)
        # Any split past the branch costs just t_1 (everything else is dead).
        assert costs[2] == pytest.approx(costs[3], rel=1e-9)
        assert costs[3] == pytest.approx(prof.t_e[1])

    def test_p_zero_equals_plain_dnn(self):
        probs_zero = make_profile([0.01, 0.02], [1e5, 1e4, 1e3], [1], [0.0])
        no_branch = make_profile([0.01, 0.02], [1e5, 1e4, 1e3], [], [])
        np.testing.assert_allclose(
            expected_time_all_splits(probs_zero), expected_time_all_splits(no_branch)
        )


class TestGraphEquivalence:
    def test_graph_cost_equals_closed_form_all_splits(self):
        """Every input->output path family in G'_BDNN prices Eq. 5/6."""
        rng = np.random.default_rng(0)
        for _ in range(20):
            n = int(rng.integers(2, 9))
            t_c = rng.uniform(1e-3, 1e-1, n)
            alpha = rng.uniform(1e3, 1e6, n + 1)
            k = int(rng.integers(0, n))  # number of branches
            pos = sorted(rng.choice(np.arange(1, n), size=k, replace=False).tolist())
            probs = rng.uniform(0, 1, k).tolist()
            prof = make_profile(t_c, alpha, pos, probs, gamma=float(rng.uniform(1, 1000)))
            plan_sp = shortest_path_plan(prof)  # asserts graph == closed form
            plan_bf = brute_force_split(prof)
            assert plan_sp.split_layer == plan_bf.split_layer or (
                plan_sp.expected_time_s
                == pytest.approx(plan_bf.expected_time_s, rel=1e-9)
            )

    def test_graph_shapes(self):
        prof = make_profile([0.1, 0.2, 0.3], [1e5, 1e4, 1e4, 1e3], [1], [0.5])
        g = build_partition_graph(prof)
        assert "input" in g.adj and "output" in g.adj
        cost, path = dijkstra(g)
        assert path[0] == "input" and path[-1] == "output"
        assert cost >= 0

    @settings(max_examples=200, deadline=None)
    @given(
        n=st.integers(2, 10),
        data=st.data(),
    )
    def test_property_dijkstra_is_optimal(self, n, data):
        t_c = data.draw(
            st.lists(st.floats(1e-4, 1.0), min_size=n, max_size=n), label="t_c"
        )
        alpha = data.draw(
            st.lists(st.floats(1.0, 1e7), min_size=n + 1, max_size=n + 1), label="alpha"
        )
        k = data.draw(st.integers(0, n - 1), label="k")
        pos = data.draw(
            st.lists(
                st.integers(1, n - 1), min_size=k, max_size=k, unique=True
            ),
            label="pos",
        )
        probs = data.draw(
            st.lists(st.floats(0.0, 1.0), min_size=k, max_size=k), label="p"
        )
        gamma = data.draw(st.floats(1.0, 1e4), label="gamma")
        bw = data.draw(st.floats(1e5, 1e10), label="bw")
        prof = make_profile(t_c, alpha, sorted(pos), probs, gamma=gamma, bw=bw)
        plan = shortest_path_plan(prof)
        oracle = brute_force_split(prof)
        assert plan.expected_time_s == pytest.approx(
            oracle.expected_time_s, rel=1e-9, abs=1e-12
        )


class TestJaxSolver:
    def test_matches_numpy(self):
        rng = np.random.default_rng(1)
        for _ in range(10):
            n = int(rng.integers(2, 12))
            t_c = np.concatenate([[0.0], rng.uniform(1e-3, 1e-1, n)])
            alpha = rng.uniform(1e3, 1e6, n + 1)
            p = np.zeros(n + 1)
            for i in rng.choice(np.arange(1, n), size=min(3, n - 1), replace=False):
                p[i] = rng.uniform(0, 1)
            gamma, bw = 50.0, 5.85e6
            branches = [i for i in range(1, n) if p[i] > 0]
            prof = make_profile(
                t_c[1:], alpha, branches, [p[i] for i in branches], gamma=gamma, bw=bw
            )
            ref = expected_time_all_splits(prof)
            got = chain_costs_jax(
                jnp.asarray(t_c), jnp.asarray(alpha), jnp.asarray(p),
                jnp.asarray(gamma), jnp.asarray(bw),
            )
            np.testing.assert_allclose(np.asarray(got), ref, rtol=1e-5)

    def test_solve_returns_argmin(self):
        t_c = jnp.array([0.0, 0.01, 0.02, 0.03])
        alpha = jnp.array([1e6, 1e4, 1e3, 1e2])
        p = jnp.zeros(4)
        s, t = solve_chain_jax(t_c, alpha, p, jnp.asarray(100.0), jnp.asarray(1e6))
        costs = chain_costs_jax(t_c, alpha, p, jnp.asarray(100.0), jnp.asarray(1e6))
        assert int(s) == int(np.argmin(np.asarray(costs)))
        assert float(t) == pytest.approx(float(np.min(np.asarray(costs))))


class TestPartitionerAPI:
    def test_with_modifiers(self):
        prof = make_profile([0.01, 0.02, 0.03], [1e6, 1e5, 1e4, 1e3], [1], [0.5])
        part = Partitioner(prof)
        p1 = part.solve()
        p2 = part.with_gamma(1000.0).solve()
        # A much slower edge can only move the split toward the cloud.
        assert p2.split_layer <= p1.split_layer
        p3 = part.with_exit_probs([1.0]).solve()
        assert p3.expected_time_s <= p1.expected_time_s + 1e-12

    def test_branch_compute_increases_cost(self):
        base = make_profile([0.01, 0.02, 0.03], [1e6, 1e5, 1e4, 1e3], [1], [0.5])
        withbc = make_profile(
            [0.01, 0.02, 0.03], [1e6, 1e5, 1e4, 1e3], [1], [0.5],
            include_bc=True, bc=[0.005],
        )
        c0 = expected_time_all_splits(base)
        c1 = expected_time_all_splits(withbc)
        # Branch compute charges only splits strictly beyond the branch.
        np.testing.assert_allclose(c1[:2], c0[:2])
        assert (c1[2:] > c0[2:]).all()
