"""End-to-end behaviour tests for the paper's system: calibration ->
cost model -> shortest-path plan -> partitioned execution, plus the
equivalence between partitioned and monolithic decode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import (
    LayerCost,
    Partitioner,
    build_cost_profile,
)
from repro.models import model as M
from repro.serving.engine import ServingEngine
from repro.serving.partitioned import PartitionedServer


@pytest.fixture(scope="module")
def small_model():
    cfg = get_smoke_config("phi3_mini_3_8b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


class TestPartitionedEquivalence:
    """A split must not change the computation — only where it runs."""

    @pytest.mark.parametrize("arch", ["phi3_mini_3_8b", "mamba2_130m", "zamba2_1_2b"])
    def test_partitioned_decode_matches_monolithic(self, arch):
        cfg = get_smoke_config(arch)
        params = M.init_params(jax.random.PRNGKey(1), cfg)
        batch, ctx = 4, 32
        tok = jax.random.randint(jax.random.PRNGKey(2), (batch, 1), 0, cfg.vocab_size)

        # Monolithic decode.
        caches0 = M.init_caches(cfg, batch, ctx)
        mono = M.decode_step(params, tok, jnp.asarray(0, jnp.int32), caches0, cfg)

        # Partitioned at layer 1.
        srv = PartitionedServer(cfg, params, split_layer=1)
        caches1 = M.init_caches(cfg, batch, ctx)
        rep, _ = srv.step(tok, 0, caches1)

        mono_tok = np.asarray(jnp.argmax(mono["logits"], -1))
        # Sequences that exited on the edge emit branch tokens; everything
        # that crossed the cut must match the monolithic forward exactly.
        crossed = ~rep.exited_on_edge
        assert crossed.any()
        np.testing.assert_array_equal(rep.tokens[crossed], mono_tok[crossed])

    def test_edge_only_and_cloud_only_bytes(self, small_model):
        cfg, params = small_model
        batch = 4
        total = cfg.num_layers
        tok = jnp.zeros((batch, 1), jnp.int32)

        srv0 = PartitionedServer(cfg, params, 0)
        rep0, _ = srv0.step(tok, 0, M.init_caches(cfg, batch, 32))
        assert rep0.shipped == batch  # everything goes to the cloud

        srvN = PartitionedServer(cfg, params, total)
        repN, _ = srvN.step(tok, 0, M.init_caches(cfg, batch, 32))
        assert repN.shipped == 0 and repN.bytes_shipped == 0.0


class TestCalibrationLoop:
    def test_engine_stats_feed_partitioner(self, small_model):
        cfg, params = small_model
        engine = ServingEngine(cfg, params, context_len=64)
        state = engine.start(
            {"tokens": jax.random.randint(jax.random.PRNGKey(3), (4, 8), 0,
                                          cfg.vocab_size)}
        )
        _, stats = engine.decode(state, steps=4)
        assert stats.total == 4 * 4
        p_k = stats.conditional_probs()
        assert p_k.shape == (len(cfg.branch_layers),)
        assert ((0 <= p_k) & (p_k <= 1)).all()

        costs = [LayerCost(f"l{i}", 0, 0, cfg.d_model * 2.0, 1e-3)
                 for i in range(cfg.num_layers)]
        prof = build_cost_profile(costs, cfg.branch_layers, p_k, "4g", 10.0, 64.0)
        plan = Partitioner(prof).solve()
        assert 0 <= plan.split_layer <= cfg.num_layers

    def test_higher_exit_prob_never_hurts(self):
        """Optimal E[T] is non-increasing in p (more exits, less shipped)."""
        costs = [LayerCost(f"l{i}", 0, 0, 2048.0, 1e-3) for i in range(8)]
        last = np.inf
        for p in (0.0, 0.3, 0.6, 0.9, 1.0):
            prof = build_cost_profile(costs, (2,), [p], "3g", 100.0, 1e6)
            t = Partitioner(prof).solve().expected_time_s
            assert t <= last + 1e-12
            last = t


class TestServingEngine:
    def test_decode_is_deterministic(self, small_model):
        cfg, params = small_model
        engine = ServingEngine(cfg, params, context_len=64)
        toks = jax.random.randint(jax.random.PRNGKey(4), (2, 8), 0, cfg.vocab_size)
        out1, _ = engine.decode(engine.start({"tokens": toks}), steps=6)
        out2, _ = engine.decode(engine.start({"tokens": toks}), steps=6)
        np.testing.assert_array_equal(out1, out2)

    def test_prefill_matches_forward(self, small_model):
        """Prefill last-position logits == trunk forward last position."""
        cfg, params = small_model
        toks = jax.random.randint(jax.random.PRNGKey(5), (2, 12), 0, cfg.vocab_size)
        caches = M.init_caches(cfg, 2, 32)
        logits, _ = M.prefill(params, {"tokens": toks}, cfg, caches)

        from repro.models.layers import norm_apply
        from repro.models.model import _embed_inputs, _unembed, run_trunk

        h, pos = _embed_inputs(params, {"tokens": toks}, cfg)
        h2, _, _, _ = run_trunk(params, h, cfg, pos, None)
        hF = norm_apply(cfg.norm_type, params["final_norm"], h2)
        ref = _unembed(params, hF[:, -1:], cfg)
        np.testing.assert_allclose(
            np.asarray(logits, np.float32), np.asarray(ref, np.float32),
            rtol=2e-2, atol=2e-2,
        )

    def test_decode_after_prefill_consistency(self):
        """Stepwise decode logits match teacher-forced prefill logits."""
        cfg = get_smoke_config("olmo_1b")
        params = M.init_params(jax.random.PRNGKey(6), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(7), (2, 8), 0, cfg.vocab_size)

        caches = M.init_caches(cfg, 2, 32)
        logits_p, caches = M.prefill(params, {"tokens": toks}, cfg, caches)
        nxt = jnp.argmax(logits_p[:, 0], -1).astype(jnp.int32)[:, None]
        out = M.decode_step(params, nxt, jnp.asarray(8, jnp.int32), caches, cfg)

        ext = jnp.concatenate([toks, nxt], axis=1)
        caches2 = M.init_caches(cfg, 2, 32)
        logits_tf, _ = M.prefill(params, {"tokens": ext}, cfg, caches2)
        np.testing.assert_allclose(
            np.asarray(out["logits"], np.float32),
            np.asarray(logits_tf[:, 0], np.float32),
            rtol=5e-2, atol=5e-2,
        )
