"""Mesh-sharded tier segments (serving/tiers.py "Mesh-sharded tier
segments"): sharded-vs-single-device trajectory equivalence on a virtual
CPU mesh, the one-host-sync and no-re-jit invariants under SPMD, mesh
construction overrides, and the sharding-aware partition-cost terms
(``TierSpec.devices`` / ``ici_bps``) in the lattice solver.

The multi-device cases need virtual devices *before jax initializes*:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest -q tests/test_sharded_tiers.py

(``make test-sharded`` / the tools/ci.sh multi-device lane do this); under
a plain single-device run they skip.  The cost-model tests always run.

SPMD partial-sum all-reduces may reorder float accumulation, so the
equivalence contract is *trajectory* identity — greedy tokens, exit
masks, shipped counts per step — not bitwise logits.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.multitier import (
    _COLLECTIVES_PER_LAYER,
    TierSpec,
    _collective_seconds,
    expected_time_multitier,
    solve_multitier,
)
from repro.launch.mesh import make_local_mesh, mesh_devices
from repro.models import model as M
from repro.serving import MultiTierServer, PartitionedServer

multi_device = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8",
)


@pytest.fixture(scope="module")
def gqa_model():
    """4-layer GQA trunk (qwen3_8b smoke), branches after v_1 and v_3."""
    cfg = dataclasses.replace(
        get_smoke_config("qwen3_8b"), num_layers=4, branch_layers=(1, 3)
    )
    return cfg, M.init_params(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def moe_model():
    """4-layer MoE trunk (qwen3_moe smoke), branches after v_1 and v_3."""
    cfg = dataclasses.replace(
        get_smoke_config("qwen3_moe_30b_a3b"), num_layers=4,
        branch_layers=(1, 3),
    )
    return cfg, M.init_params(jax.random.PRNGKey(1), cfg)


def _toks(cfg, batch=4, seed=2):
    return jax.random.randint(
        jax.random.PRNGKey(seed), (batch, 1), 0, cfg.vocab_size
    )


def _trajectory(srv, cfg, steps=4, batch=4):
    """Greedy-decode ``steps`` and record (tokens, exited, shipped)/step."""
    caches = srv.executor.shard_caches(M.init_caches(cfg, batch, 32))
    tok = _toks(cfg, batch)
    out = []
    for i in range(steps):
        rep, caches = srv.step(tok, i, caches)
        exited = getattr(rep, "exited", getattr(rep, "exited_on_edge", None))
        shipped = getattr(
            rep, "shipped_per_hop", (getattr(rep, "shipped", 0),)
        )
        out.append((rep.tokens.copy(), np.asarray(exited).copy(),
                    tuple(shipped)))
        tok = jnp.asarray(rep.tokens[:, None])
    return out


def _assert_same_trajectory(ref, got):
    assert len(ref) == len(got)
    for step, ((rt, re, rs), (gt, ge, gs)) in enumerate(zip(ref, got)):
        np.testing.assert_array_equal(gt, rt, err_msg=f"tokens @ step {step}")
        np.testing.assert_array_equal(ge, re, err_msg=f"exits @ step {step}")
        assert gs == rs, f"shipped @ step {step}"


@multi_device
class TestShardedEquivalence:
    """Sharded segments reproduce the single-device trajectory exactly."""

    @pytest.mark.parametrize("compaction", ["bucketed", "off"])
    def test_k2_partitioned_gqa(self, gqa_model, compaction):
        cfg, params = gqa_model
        ref = _trajectory(
            PartitionedServer(cfg, params, 2, compaction=compaction), cfg
        )
        srv = PartitionedServer(
            cfg, params, 2, compaction=compaction, mesh=make_local_mesh()
        )
        assert srv.executor.sharded
        assert srv.tier_devices == (1, jax.device_count())
        _assert_same_trajectory(ref, _trajectory(srv, cfg))

    @pytest.mark.parametrize("compaction", ["bucketed", "off"])
    def test_k3_multitier_moe(self, moe_model, compaction):
        cfg, params = moe_model
        tiers = [
            TierSpec("device", 200.0, 1e6),
            TierSpec("edge", 20.0, 2e7),
            TierSpec("cloud", 1.0, devices=jax.device_count(), ici_bps=1e11),
        ]
        ref = _trajectory(
            MultiTierServer(cfg, params, tiers, (1, 3),
                            compaction=compaction), cfg
        )
        srv = MultiTierServer(
            cfg, params, tiers, (1, 3), compaction=compaction,
            mesh=make_local_mesh(),
        )
        assert srv.executor.sharded
        _assert_same_trajectory(ref, _trajectory(srv, cfg))

    def test_k1_engine_matches_unsharded(self, gqa_model):
        from repro.serving import ServingEngine

        cfg, params = gqa_model
        prompts = {"tokens": jax.random.randint(
            jax.random.PRNGKey(3), (4, 6), 0, cfg.vocab_size)}

        def run(mesh):
            eng = ServingEngine(cfg, params, context_len=64, mesh=mesh)
            toks, stats = eng.decode(eng.start(prompts), steps=5)
            return np.asarray(toks), eng.host_syncs

        ref, ref_syncs = run(None)
        got, got_syncs = run(make_local_mesh())
        np.testing.assert_array_equal(got, ref)
        assert got_syncs == ref_syncs == 5

    def test_one_host_sync_per_sharded_step(self, gqa_model):
        cfg, params = gqa_model
        srv = PartitionedServer(cfg, params, 2, mesh=make_local_mesh())
        caches = srv.executor.shard_caches(M.init_caches(cfg, 4, 32))
        tok = _toks(cfg)
        for i in range(4):
            rep, caches = srv.step(tok, i, caches)
            tok = jnp.asarray(rep.tokens[:, None])
        assert srv.executor.host_syncs == 4

    def test_hot_swap_keeps_sharded_segment_fns(self, moe_model):
        cfg, params = moe_model
        tiers = [TierSpec("d", 100.0, 1e6), TierSpec("e", 10.0, 1e7),
                 TierSpec("c", 1.0)]
        srv = MultiTierServer(
            cfg, params, tiers, (1, 3), mesh=make_local_mesh()
        )
        cloud_fn = srv.executor.segment_fn(2)
        srv.install_cuts((2, 3))  # move only the first cut
        assert srv.executor.segment_fn(2) is cloud_fn

    def test_sharded_resolves_kernels_off(self, gqa_model):
        """Pallas decode kernels are single-device; sharded segments must
        take the jnp lowering regardless of the requested flag."""
        cfg, params = gqa_model
        srv = PartitionedServer(
            cfg, params, 2, mesh=make_local_mesh(), use_kernels=True
        )
        assert srv.executor.use_kernels is False

    def test_sharded_params_actually_span_devices(self, gqa_model):
        """The policy must place at least one trunk tensor across >1
        device — otherwise the "sharded" run is silently replicated."""
        cfg, params = gqa_model
        srv = PartitionedServer(cfg, params, 2, mesh=make_local_mesh())
        widths = {
            len(leaf.sharding.device_set)
            for leaf in jax.tree_util.tree_leaves(srv.params)
        }
        assert max(widths) == jax.device_count()


@multi_device
class TestMeshConstruction:
    def test_local_mesh_axis_overrides(self):
        mesh = make_local_mesh(data=2, model=4)
        assert dict(mesh.shape) == {"data": 2, "model": 4}
        assert mesh_devices(mesh) == 8

    def test_default_is_pure_model_parallel(self):
        mesh = make_local_mesh()
        assert dict(mesh.shape) == {"data": 1, "model": jax.device_count()}

    def test_over_request_raises(self):
        with pytest.raises(ValueError, match="only"):
            make_local_mesh(data=jax.device_count(), model=2)

    def test_partial_override_fills_remainder(self):
        mesh = make_local_mesh(model=2)
        assert dict(mesh.shape) == {"data": jax.device_count() // 2,
                                    "model": 2}


class TestShardedTierCosts:
    """TierSpec.devices/ici_bps: shard-width compute + intra-tier
    collective terms move the optimal cut (and are priced honestly).
    Pure cost model — no devices needed."""

    def _profile(self, n=8):
        t_c = np.concatenate([[0.0], np.full(n, 2e-2)])
        alpha = np.full(n + 1, 4e4)  # 40 KB residual crossing any cut
        p = np.zeros(n + 1)
        return t_c, alpha, p

    def test_shard_width_moves_cut(self):
        """With equal per-chip speed the solver never ships (the hop buys
        nothing); widening the cloud to an 8-way mesh makes shipping pay
        for itself, and the new cut is verified cheaper under the sharded
        cost."""
        t_c, alpha, p = self._profile()
        n = len(t_c) - 1
        uplink = 4e7  # 8 ms hop vs 20 ms/layer saved on the wide tier
        flat = [TierSpec("edge", 1.0, uplink), TierSpec("cloud", 1.0)]
        wide = [
            TierSpec("edge", 1.0, uplink),
            TierSpec("cloud", 1.0, devices=8, ici_bps=1e11),
        ]
        plan_flat = solve_multitier(t_c, alpha, p, flat)
        plan_wide = solve_multitier(t_c, alpha, p, wide)
        assert plan_flat.cut_after == (n,)  # never ship: no compute gain
        assert plan_wide.cut_after != plan_flat.cut_after
        at_wide = expected_time_multitier(
            t_c, alpha, p, wide, plan_wide.cut_after
        )
        at_flat = expected_time_multitier(
            t_c, alpha, p, wide, plan_flat.cut_after
        )
        assert at_wide < at_flat
        assert plan_wide.expected_time_s == pytest.approx(at_wide)

    def test_dead_ici_prices_sharded_tier_unusable(self):
        """devices > 1 with no interconnect = infinite collectives: the
        solver routes every layer off that tier (mirrors _hop_seconds'
        dead-uplink policy)."""
        t_c, alpha, p = self._profile()
        n = len(t_c) - 1
        tiers = [
            TierSpec("edge", 1.0, 4e7),
            TierSpec("cloud", 1.0, devices=8, ici_bps=0.0),
        ]
        plan = solve_multitier(t_c, alpha, p, tiers)
        assert plan.cut_after == (n,)
        assert np.isfinite(plan.expected_time_s)

    def test_collective_term_scales_with_ring(self):
        assert _collective_seconds(1, 8e4, 1e9) == 0.0
        assert _collective_seconds(4, 0.0, 1e9) == 0.0
        assert _collective_seconds(2, 8e4, 0.0) == np.inf
        t2 = _collective_seconds(2, 8e4, 1e9)
        t8 = _collective_seconds(8, 8e4, 1e9)
        # ring factor 2(d-1)/d: t8/t2 = (7/4) / 1 = 1.75
        assert t8 == pytest.approx(t2 * 1.75)

    def test_estimator_matches_manual_sharded_cost(self):
        """expected_time_multitier with a sharded last tier = hand-computed
        per-layer (gamma*t_c/d + collectives) + hop."""
        t_c, alpha, p = self._profile(4)
        d, ici, uplink = 4, 5e10, 1e8
        tiers = [
            TierSpec("edge", 2.0, uplink),
            TierSpec("cloud", 1.0, devices=d, ici_bps=ici),
        ]
        s = 2
        got = expected_time_multitier(t_c, alpha, p, tiers, (s,))
        ring = 2.0 * (d - 1) / d
        coll = _COLLECTIVES_PER_LAYER * ring * alpha[3] * 8.0 / ici
        want = (
            2.0 * (t_c[1] + t_c[2])  # edge layers
            + alpha[s] * 8.0 / uplink  # hop
            + sum(t_c[i] / d + coll for i in (3, 4))  # sharded cloud
        )
        assert got == pytest.approx(want, rel=1e-12)

    def test_profiler_devices_term(self):
        """HardwareSpec.roofline_time/collective_time mirror the lattice
        terms: d-way split plus ring collectives on the output bytes."""
        from repro.core.profiler import HardwareSpec

        hw = HardwareSpec("t", peak_flops=1e12, hbm_bw=1e11, link_bw=1e10)
        base = hw.roofline_time(1e9, 1e7)
        assert hw.roofline_time(1e9, 1e7, devices=4) == pytest.approx(
            base / 4
        )
        assert hw.collective_time(1e6, 1) == 0.0
        want = 2.0 * (2.0 * 3 / 4) * 1e6 / 1e10
        assert hw.collective_time(1e6, 4) == pytest.approx(want)


@multi_device
class TestPolicyLowering:
    """Decode-step lowering under each config's policy never crashes: the
    rule tables may replicate (divisibility fallback) but must never
    produce a spec XLA rejects.  Smoke configs keep the compile cheap;
    the mesh is the real virtual-device mesh, so SPMD propagation runs."""

    @pytest.mark.parametrize(
        "arch", __import__("repro.configs", fromlist=["ARCH_IDS"]).ARCH_IDS
    )
    def test_decode_step_compiles_sharded(self, arch):
        from repro.sharding.ctx import activation_sharding
        from repro.sharding.policy import make_policy

        cfg = get_smoke_config(arch)
        mesh = make_local_mesh()
        pol = make_policy(mesh, cfg)
        params = pol.shard_params(M.init_params(jax.random.PRNGKey(0), cfg))
        caches = pol.shard_caches(M.init_caches(cfg, 4, 32))
        tok = _toks(cfg)
        pos = jnp.asarray(0, jnp.int32)

        def step(p, t, c):
            with activation_sharding(mesh, pol.batch_axes, pol.model_axis):
                return M.decode_step(p, t, pos, c, cfg)

        out = jax.jit(step).lower(params, tok, caches).compile()(
            params, tok, caches
        )
        assert np.all(np.isfinite(np.asarray(out["logits"], np.float32)))
