"""K-tier lattice solver: K=2 must reproduce the paper's solution; K=3
verified against brute force over cut pairs."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import BranchSpec, CostProfile, NetworkProfile, brute_force_split
from repro.core.multitier import TierSpec, solve_multitier


def random_chain(rng, n, with_branches=True):
    t_c = np.concatenate([[0.0], rng.uniform(1e-4, 1e-1, n)])
    alpha = rng.uniform(1e2, 1e6, n + 1)
    p = np.zeros(n + 1)
    if with_branches and n > 2:
        for i in rng.choice(np.arange(1, n), size=min(2, n - 1), replace=False):
            p[i] = rng.uniform(0, 1)
    return t_c, alpha, p


class TestTwoTierEquivalence:
    @settings(max_examples=80, deadline=None)
    @given(n=st.integers(2, 12), seed=st.integers(0, 2**16),
           gamma=st.floats(1.0, 1000.0), bw=st.floats(1e5, 1e9))
    def test_matches_paper_solver(self, n, seed, gamma, bw):
        rng = np.random.default_rng(seed)
        t_c, alpha, p = random_chain(rng, n)
        tiers = [TierSpec("edge", gamma, bw), TierSpec("cloud", 1.0)]
        plan = solve_multitier(t_c, alpha, p, tiers)

        branches = tuple(
            BranchSpec(i, float(p[i])) for i in range(1, n) if p[i] > 0
        )
        prof = CostProfile(
            t_c=t_c, alpha=alpha, branches=branches, gamma=gamma,
            network=NetworkProfile("t", bw),
        )
        ref = brute_force_split(prof)
        assert plan.expected_time_s == pytest.approx(
            ref.expected_time_s, rel=1e-9, abs=1e-12
        )
        assert plan.cut_after == (ref.split_layer,)


class TestThreeTier:
    @settings(max_examples=40, deadline=None)
    @given(n=st.integers(2, 8), seed=st.integers(0, 2**16))
    def test_matches_bruteforce_two_cuts(self, n, seed):
        rng = np.random.default_rng(seed)
        t_c, alpha, p = random_chain(rng, n)
        tiers = [
            TierSpec("device", 200.0, 1e6),
            TierSpec("edge", 20.0, 2e7),
            TierSpec("cloud", 1.0),
        ]
        plan = solve_multitier(t_c, alpha, p, tiers)

        surv = np.cumprod(1.0 - p)
        reach = np.concatenate([[1.0], surv[:-1]])

        best = np.inf
        for s1 in range(0, n + 1):
            for s2 in range(s1, n + 1):
                cost = 0.0
                for i in range(1, n + 1):
                    if i <= s1:
                        cost += reach[i] * tiers[0].gamma * t_c[i]
                    elif i <= s2:
                        cost += reach[i] * tiers[1].gamma * t_c[i]
                    else:
                        # cloud evaluates no branches: frozen at the wire
                        cost += reach[s2] * tiers[2].gamma * t_c[i]
                # branch at a cut is not evaluated: wire survival reach[s].
                # A hop only happens if a later tier actually runs layers
                # (s == n means "never ship", e.g. device/edge-only).
                if s1 < n or s2 < n:
                    cost += reach[s1] * alpha[s1] * 8 / tiers[0].uplink_bps
                if s2 < n:
                    cost += reach[s2] * alpha[s2] * 8 / tiers[1].uplink_bps
                best = min(best, cost)
        assert plan.expected_time_s == pytest.approx(best, rel=1e-9, abs=1e-12)

    def test_monotone_tiers(self):
        """Layers never move backward through tiers."""
        rng = np.random.default_rng(0)
        t_c, alpha, p = random_chain(rng, 10)
        tiers = [TierSpec("d", 100.0, 5e6), TierSpec("e", 10.0, 5e7),
                 TierSpec("c", 1.0)]
        plan = solve_multitier(t_c, alpha, p, tiers)
        assert all(a <= b for a, b in zip(plan.tier_of_layer, plan.tier_of_layer[1:]))
