"""Training substrate: optimizers, schedules, accumulation, checkpointing."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.training.checkpoint import (
    checkpoint_manifest,
    restore_checkpoint,
    save_checkpoint,
)
from repro.training.optimizer import (
    adafactor,
    adamw,
    cosine_schedule,
    make_optimizer,
)
from repro.training.train_loop import init_train_state, make_train_step


def quad_params():
    return {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray(1.5)}


class TestOptimizers:
    @pytest.mark.parametrize("name", ["adamw", "adafactor"])
    def test_optimizer_minimizes_quadratic(self, name):
        opt = make_optimizer(name, lr=0.1 if name == "adamw" else 0.5)
        params = quad_params()
        state = opt.init(params)

        def loss(p):
            return jnp.sum(p["w"] ** 2) + p["b"] ** 2

        l0 = float(loss(params))
        for step in range(200):
            g = jax.grad(loss)(params)
            params, state = opt.update(g, state, params, jnp.asarray(step))
        assert float(loss(params)) < l0 * 1e-2

    def test_adamw_weight_decay_shrinks(self):
        opt = adamw(lr=0.0, weight_decay=0.0)  # lr=0: nothing moves
        params = quad_params()
        state = opt.init(params)
        g = jax.tree_util.tree_map(jnp.zeros_like, params)
        p2, _ = opt.update(g, state, params, jnp.asarray(0))
        np.testing.assert_allclose(np.asarray(p2["w"]), np.asarray(params["w"]))

    def test_adafactor_factored_state_shape(self):
        opt = adafactor()
        params = {"m": jnp.zeros((8, 16)), "v": jnp.zeros((5,))}
        st = opt.init(params)
        assert st["m"]["vr"].shape == (8,)
        assert st["m"]["vc"].shape == (16,)
        assert st["v"]["v"].shape == (5,)

    def test_cosine_schedule(self):
        lr = cosine_schedule(1e-3, warmup=10, total=100)
        assert float(lr(jnp.asarray(0))) == 0.0
        assert float(lr(jnp.asarray(10))) == pytest.approx(1e-3, rel=1e-5)
        assert float(lr(jnp.asarray(100))) == pytest.approx(1e-4, rel=1e-3)


class TestTrainStep:
    def test_loss_decreases(self):
        cfg = get_smoke_config("olmo_1b")
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        opt = make_optimizer("adamw", lr=1e-3)
        state = init_train_state(params, opt)
        step = jax.jit(make_train_step(cfg, opt))
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                         cfg.vocab_size),
        }
        batch["labels"] = batch["tokens"]
        losses = []
        for _ in range(12):
            state, metrics = step(state, batch)  # same batch -> must overfit
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0]
        assert int(state["step"]) == 12

    def test_grad_accum_matches_full_batch(self):
        """accum=2 over a batch == accum=1 on the same batch (same grads
        modulo accumulation-order float error)."""
        import dataclasses

        cfg = get_smoke_config("phi3_mini_3_8b")
        cfg1 = dataclasses.replace(cfg, grad_accum=1, dtype="float32")
        cfg2 = dataclasses.replace(cfg, grad_accum=2, dtype="float32")
        params = M.init_params(jax.random.PRNGKey(2), cfg)
        opt = make_optimizer("adamw", lr=1e-3)
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(3), (4, 16), 0,
                                         cfg.vocab_size)
        }
        batch["labels"] = batch["tokens"]
        s1, m1 = make_train_step(cfg1, opt)(init_train_state(params, opt), batch)
        s2, m2 = make_train_step(cfg2, opt)(init_train_state(params, opt), batch)
        assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=5e-3)
        # Parameters end up close (not identical: per-microbatch mean vs
        # global mean weighting is equivalent only for equal-sized micros,
        # which holds here, so they should be very close).
        for a, b in zip(jax.tree_util.tree_leaves(s1["params"]),
                        jax.tree_util.tree_leaves(s2["params"])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=5e-2, atol=5e-4)


class TestCheckpoint:
    def test_roundtrip_and_manifest(self):
        tree = {
            "a": {"w": np.arange(6, dtype=np.float32).reshape(2, 3)},
            "b": np.asarray(7, np.int32),
        }
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "ck.npz")
            save_checkpoint(path, tree, step=42)
            man = checkpoint_manifest(path)
            assert man["step"] == 42
            like = jax.tree_util.tree_map(
                lambda a: jax.ShapeDtypeStruct(np.shape(a), np.asarray(a).dtype), tree
            )
            out = restore_checkpoint(path, like)
            np.testing.assert_array_equal(out["a"]["w"], tree["a"]["w"])
            assert out["b"] == 7

    def test_shape_mismatch_raises(self):
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "ck.npz")
            save_checkpoint(path, {"w": np.zeros((2, 2))})
            bad = {"w": jax.ShapeDtypeStruct((3, 2), np.float32)}
            with pytest.raises(ValueError):
                restore_checkpoint(path, bad)

    def test_missing_key_raises(self):
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "ck.npz")
            save_checkpoint(path, {"w": np.zeros((2,))})
            with pytest.raises(KeyError):
                restore_checkpoint(
                    path,
                    {"w": jax.ShapeDtypeStruct((2,), np.float32),
                     "v": jax.ShapeDtypeStruct((2,), np.float32)},
                )
