"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
asserting output shapes and no NaNs.  (Deliverable f.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import model as M


def make_inputs(cfg, batch=2, seq=16, key=None):
    key = key or jax.random.PRNGKey(0)
    inputs = {}
    if cfg.frontend == "vision":
        text = seq - cfg.num_patches
        assert text > 0
        inputs["tokens"] = jax.random.randint(key, (batch, text), 0, cfg.vocab_size)
        inputs["patch_embeds"] = jax.random.normal(
            key, (batch, cfg.num_patches, cfg.d_model), jnp.float32
        )
        labels = jnp.pad(inputs["tokens"], ((0, 0), (0, 0)))
        inputs["labels"] = jnp.concatenate(
            [jnp.zeros((batch, cfg.num_patches), jnp.int32), labels], axis=1
        )
        # loss is computed on the text slice only; labels aligned to full seq.
        inputs["labels"] = inputs["tokens"]
    elif cfg.frontend == "audio":
        inputs["tokens"] = jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)
        inputs["frame_embeds"] = jax.random.normal(
            key, (batch, cfg.encoder_seq_len, cfg.d_model), jnp.float32
        )
        inputs["labels"] = inputs["tokens"]
    else:
        inputs["tokens"] = jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)
        inputs["labels"] = inputs["tokens"]
    return inputs


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(42)


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestSmoke:
    def test_train_step_shapes_and_finite(self, arch, rng):
        cfg = get_smoke_config(arch)
        params = M.init_params(rng, cfg)
        inputs = make_inputs(cfg)
        out = M.forward_train(params, inputs, cfg)
        assert out["loss"].shape == ()
        assert np.isfinite(float(out["loss"])), f"{arch}: loss not finite"
        assert np.isfinite(float(out["main_loss"]))
        for k, v in out["branch_losses"].items():
            assert np.isfinite(float(v)), f"{arch}: branch {k} loss not finite"
        # Branch joint loss: every configured branch produced a loss.
        for b in cfg.branch_layers:
            assert f"branch_{b}" in out["branch_losses"]

    def test_grads_finite(self, arch, rng):
        cfg = get_smoke_config(arch)
        params = M.init_params(rng, cfg)
        inputs = make_inputs(cfg)

        def loss_fn(p):
            return M.forward_train(p, inputs, cfg)["loss"]

        grads = jax.grad(loss_fn)(params)
        flat = jax.tree_util.tree_leaves(grads)
        assert flat, "no grads"
        for g in flat:
            assert np.all(np.isfinite(np.asarray(g, dtype=np.float32))), (
                f"{arch}: non-finite grad"
            )

    def test_prefill_then_decode(self, arch, rng):
        cfg = get_smoke_config(arch)
        params = M.init_params(rng, cfg)
        batch, seq = 2, 16
        inputs = make_inputs(cfg, batch, seq)
        total_len = seq if cfg.frontend != "vision" else seq
        caches = M.init_caches(cfg, batch, 64)
        logits, caches = M.prefill(params, inputs, cfg, caches)
        assert logits.shape == (batch, 1, cfg.vocab_size)
        assert np.all(np.isfinite(np.asarray(logits, np.float32)))
        # one decode step
        tok = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)[:, None]
        pos = jnp.asarray(
            seq if cfg.frontend != "vision" else cfg.num_patches + seq - cfg.num_patches,
            jnp.int32,
        )
        out = M.decode_step(params, tok, pos, caches, cfg)
        assert out["logits"].shape == (batch, cfg.vocab_size)
        assert np.all(np.isfinite(np.asarray(out["logits"], np.float32)))
        for layer, e in out["branch_entropy"].items():
            assert e.shape == (batch,)
            assert np.all(np.isfinite(np.asarray(e, np.float32)))
        assert int(out["caches"]["length"]) == seq + 1
