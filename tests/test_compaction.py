"""Survivor-compacted tier runtime (serving/tiers.py compact->run->scatter):

  * bitwise token/exit equivalence vs the masked path, K in {1, 2, 3},
    single-step from identical cache state and multi-step in the no-exit /
    all-exit extremes;
  * bucket-boundary batches (B=1, B=bucket, B=bucket+1);
  * the 1-sync invariant and the overflow-retry escape hatch;
  * no re-jit when only survivor counts change within a bucket;
  * per-hop compaction stats, bucketed cost model, simulated uplink
    latency, and the repartition controller's drift detection;
  * the pipelined overlap mode composed with compaction: bitwise
    equivalence, the overflow-retry serial fallback, and the
    steady-state bottleneck-stage wall clock.
"""

import dataclasses
import time

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import LayerCost, NetworkProfile, build_cost_profile
from repro.core.multitier import (
    TierSpec,
    bucket_for,
    bucket_ladder,
    expected_time_multitier,
    solve_multitier,
)
from repro.models import model as M
from repro.serving import (
    MultiTierServer,
    PartitionedServer,
    RepartitionController,
    TierExecutor,
    segments_for_cuts,
)
from repro.serving.controller import exit_drift_kl


@pytest.fixture(scope="module")
def deep_model():
    """4 trunk layers, branches after v_1 and v_3 — enough structure for
    K=3 cuts, mid-tier exits, and bucket-boundary batches."""
    cfg = dataclasses.replace(
        get_smoke_config("phi3_mini_3_8b"), num_layers=4, branch_layers=(1, 3)
    )
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _toks(cfg, batch, seed=2):
    return jax.random.randint(
        jax.random.PRNGKey(seed), (batch, 1), 0, cfg.vocab_size
    )


def _mixed_threshold(cfg, params, batch=8):
    """A threshold between the observed branch entropies so exits are a
    deterministic mix (some rows exit, some survive) on the fixed seed."""
    ex = TierExecutor(cfg, params, segments_for_cuts(cfg, ()))
    res, _ = ex.step(_toks(cfg, batch), 0, M.init_caches(cfg, batch, 32))
    ents = np.concatenate([res.branch_entropy[l] for l in cfg.branch_layers])
    lo, hi = float(ents.min()), float(ents.max())
    assert hi > lo, "degenerate entropies; pick another seed"
    return (lo + hi) / 2


def _run(cfg, params, cuts, *, batch, steps, compaction, seed=2):
    ex = TierExecutor(
        cfg, params, segments_for_cuts(cfg, cuts), compaction=compaction
    )
    caches = M.init_caches(cfg, batch, 64)
    tok = _toks(cfg, batch, seed)
    out = []
    for i in range(steps):
        res, caches = ex.step(tok, i, caches)
        out.append(res)
        tok = res.tokens_dev[:, None]
    return ex, out


class TestBitwiseEquivalence:
    @pytest.mark.parametrize("cuts", [(), (2,), (1, 3), (2, 3)])
    def test_single_step_identical(self, deep_model, cuts):
        """K in {1,2,3}: one step from identical caches, mixed exits."""
        cfg0, params = deep_model
        cfg = dataclasses.replace(
            cfg0, exit_threshold=_mixed_threshold(cfg0, params)
        )
        _, [rm] = _run(cfg, params, cuts, batch=8, steps=1, compaction="off")
        exc, [rc] = _run(cfg, params, cuts, batch=8, steps=1,
                         compaction="bucketed")
        np.testing.assert_array_equal(rm.tokens, rc.tokens)
        np.testing.assert_array_equal(rm.exited, rc.exited)
        np.testing.assert_array_equal(rm.exit_tier, rc.exit_tier)
        for layer in rm.branch_take:
            np.testing.assert_array_equal(
                rm.branch_take[layer], rc.branch_take[layer]
            )

    def test_single_step_identical_with_warm_buckets(self, deep_model):
        """Equivalence also when compaction actually engages (bucket < B):
        warm the hints with one step, then compare a step from fresh
        identical caches on both paths."""
        cfg0, params = deep_model
        # A midpoint threshold doesn't guarantee >half exit *at branch 1*
        # (the only branch before the cut); sit between the 6th and 7th
        # smallest branch-1 entropies so 6 of 8 exit on the edge and the
        # cloud bucket really shrinks below the 8-row batch.
        ex0 = TierExecutor(cfg0, params, segments_for_cuts(cfg0, ()))
        r0, _ = ex0.step(_toks(cfg0, 8), 0, M.init_caches(cfg0, 8, 32))
        b1 = np.sort(r0.branch_entropy[1])
        cfg = dataclasses.replace(
            cfg0, exit_threshold=float((b1[5] + b1[6]) / 2)
        )
        exm = TierExecutor(cfg, params, segments_for_cuts(cfg, (2,)),
                           compaction="off")
        exc = TierExecutor(cfg, params, segments_for_cuts(cfg, (2,)))
        exc.step(_toks(cfg, 8), 0, M.init_caches(cfg, 8, 32))  # warm hints
        rm, _ = exm.step(_toks(cfg, 8), 0, M.init_caches(cfg, 8, 32))
        rc, _ = exc.step(_toks(cfg, 8), 0, M.init_caches(cfg, 8, 32))
        np.testing.assert_array_equal(rm.tokens, rc.tokens)
        np.testing.assert_array_equal(rm.exited, rc.exited)
        assert rc.compaction[0].bucket < 8  # compaction really engaged
        assert rc.compaction[0].survivors <= rc.compaction[0].bucket

    @pytest.mark.parametrize("cuts", [(2,), (2, 3)])
    @pytest.mark.parametrize("threshold", [0.0, 1.5])
    def test_multistep_extremes_identical(self, deep_model, cuts, threshold):
        """No-exit (threshold 0) and all-exit (1.5) regimes stay bitwise
        identical to the masked path across autoregressive steps."""
        cfg0, params = deep_model
        cfg = dataclasses.replace(cfg0, exit_threshold=threshold)
        _, outs_m = _run(cfg, params, cuts, batch=4, steps=5, compaction="off")
        exc, outs_c = _run(cfg, params, cuts, batch=4, steps=5,
                           compaction="bucketed")
        for a, b in zip(outs_m, outs_c):
            np.testing.assert_array_equal(a.tokens, b.tokens)
            np.testing.assert_array_equal(a.exited, b.exited)
        assert exc.overflow_retries == 0
        assert exc.host_syncs == 5

    def test_mixed_multistep_is_bucket_history_independent(self, deep_model):
        """The compacted semantics are a pure function of exits, never of
        bucket/hint/retry history: two executors whose hints disagree (one
        cold, one seeded with tiny stale hints that force overflow
        retries) must produce bitwise-identical trajectories.  The first
        step additionally matches the masked path exactly (after that,
        survivor rows may diverge from masked via the documented
        hole semantics — which is why this invariant matters)."""
        cfg0, params = deep_model
        cfg = dataclasses.replace(
            cfg0, exit_threshold=_mixed_threshold(cfg0, params)
        )
        _, outs_m = _run(cfg, params, (2,), batch=8, steps=4, compaction="off")
        exa, outs_a = _run(cfg, params, (2,), batch=8, steps=4,
                           compaction="bucketed")

        exb = TierExecutor(cfg, params, segments_for_cuts(cfg, (2,)))
        caches = M.init_caches(cfg, 8, 64)
        tok = _toks(cfg, 8)
        outs_b = []
        for i in range(4):
            exb._hints = {1: 1}  # stale hint: forces retry when >1 survive
            res, caches = exb.step(tok, i, caches)
            outs_b.append(res)
            tok = res.tokens_dev[:, None]

        np.testing.assert_array_equal(outs_m[0].tokens, outs_a[0].tokens)
        np.testing.assert_array_equal(outs_m[0].exited, outs_a[0].exited)
        saw_exit = False
        for a, b in zip(outs_a, outs_b):
            saw_exit |= bool(a.exited.any())
            np.testing.assert_array_equal(a.tokens, b.tokens)
            np.testing.assert_array_equal(a.exited, b.exited)
        assert saw_exit
        assert exb.overflow_retries > exa.overflow_retries


class TestBucketBoundaries:
    @pytest.mark.parametrize("batch", [1, 4, 5])
    def test_boundary_batches(self, deep_model, batch):
        """B=1, B=bucket (power of two), B=bucket+1 all stay correct in the
        all-exit regime (bucket shrinks to the 1-row floor)."""
        cfg0, params = deep_model
        cfg = dataclasses.replace(cfg0, exit_threshold=1.5)
        _, outs_m = _run(cfg, params, (2,), batch=batch, steps=3,
                         compaction="off")
        exc, outs_c = _run(cfg, params, (2,), batch=batch, steps=3,
                           compaction="bucketed")
        for a, b in zip(outs_m, outs_c):
            np.testing.assert_array_equal(a.tokens, b.tokens)
            np.testing.assert_array_equal(a.exited, b.exited)
        assert outs_c[-1].compaction[0].bucket == 1
        assert outs_c[-1].compaction[0].survivors == 0
        assert outs_c[-1].compaction[0].padded_waste == 1

    def test_ladder(self):
        assert bucket_ladder(8) == (1, 2, 4, 8)
        assert bucket_ladder(6) == (1, 2, 4, 6)
        assert bucket_ladder(1) == (1,)
        assert bucket_for(0, 8) == 1  # 1-row floor keeps cache slots moving
        assert bucket_for(3, 8) == 4
        assert bucket_for(8, 8) == 8
        assert bucket_for(5, 6) == 6


class TestSyncsAndRetries:
    def test_one_sync_per_step_with_compaction_engaged(self, deep_model):
        cfg0, params = deep_model
        cfg = dataclasses.replace(cfg0, exit_threshold=1.5)  # all exit
        exc, outs = _run(cfg, params, (2,), batch=8, steps=6,
                         compaction="bucketed")
        assert exc.host_syncs == 6
        assert exc.overflow_retries == 0
        assert outs[-1].compaction[0].bucket == 1  # really compacted

    def test_overflow_retry_is_bitwise_correct(self, deep_model):
        """An exit-rate spike (hint says 1 survivor, 8 arrive) triggers one
        retry: results still match the masked path bitwise, and the extra
        sync is counted."""
        cfg0, params = deep_model
        cfg = dataclasses.replace(cfg0, exit_threshold=0.0)  # no exits
        exm = TierExecutor(cfg, params, segments_for_cuts(cfg, (2,)),
                           compaction="off")
        exc = TierExecutor(cfg, params, segments_for_cuts(cfg, (2,)))
        cm, cc = M.init_caches(cfg, 8, 32), M.init_caches(cfg, 8, 32)
        tok = _toks(cfg, 8)
        rm, cm = exm.step(tok, 0, cm)
        rc, cc = exc.step(tok, 0, cc)
        np.testing.assert_array_equal(rm.tokens, rc.tokens)
        exc._hints = {1: 1}  # fake a stale all-exit hint
        rm, cm = exm.step(rm.tokens_dev[:, None], 1, cm)
        rc, cc = exc.step(rc.tokens_dev[:, None], 1, cc)
        np.testing.assert_array_equal(rm.tokens, rc.tokens)
        np.testing.assert_array_equal(rm.exited, rc.exited)
        assert exc.overflow_retries == 1
        assert exc.host_syncs == 3  # 1 + (1 + 1 retry)

    def test_overflow_retry_fixes_all_segments(self, deep_model):
        """Stale hints on *every* downstream segment of a K=3 stack are
        repaired by the retry loop in one pass (exact measured counts),
        with results bitwise equal to the masked path."""
        cfg0, params = deep_model
        cfg = dataclasses.replace(cfg0, exit_threshold=0.0)
        exm = TierExecutor(cfg, params, segments_for_cuts(cfg, (2, 3)),
                           compaction="off")
        exc = TierExecutor(cfg, params, segments_for_cuts(cfg, (2, 3)))
        cm, cc = M.init_caches(cfg, 8, 32), M.init_caches(cfg, 8, 32)
        tok = _toks(cfg, 8)
        rm, cm = exm.step(tok, 0, cm)
        rc, cc = exc.step(tok, 0, cc)
        exc._hints = {1: 1, 2: 1}  # both downstream tiers under-provisioned
        rm, cm = exm.step(rm.tokens_dev[:, None], 1, cm)
        rc, cc = exc.step(rc.tokens_dev[:, None], 1, cc)
        np.testing.assert_array_equal(rm.tokens, rc.tokens)
        np.testing.assert_array_equal(rm.exited, rc.exited)
        assert exc.overflow_retries == 1  # one loop iteration fixed both
        assert all(c.bucket == 8 for c in rc.compaction)

    def test_no_rejit_when_survivors_change_within_bucket(self, deep_model):
        """Steps whose survivor count moves within one bucket reuse the
        compiled segment: the trace counter must not grow."""
        cfg0, params = deep_model
        cfg = dataclasses.replace(cfg0, exit_threshold=1.5)  # 0 survivors
        exc = TierExecutor(cfg, params, segments_for_cuts(cfg, (2,)))
        caches = M.init_caches(cfg, 8, 64)
        tok = _toks(cfg, 8)
        res, caches = exc.step(tok, 0, caches)  # full-batch buckets (step 0)
        # Hints 0 and 1 both land in bucket 1; hints 3 and 4 in bucket 4.
        # With zero true survivors no step retries, so the planned bucket
        # is exactly what runs.
        for step, hint in enumerate((0, 1, 3, 4), start=1):
            exc._hints = {1: hint}
            res, caches = exc.step(res.tokens_dev[:, None], step, caches)
            assert res.compaction[0].bucket == bucket_for(hint, 8)
        assert exc.overflow_retries == 0
        # Every (spec, bucket) pair traced exactly once: the second visit
        # to bucket 1 (hint 1) and to bucket 4 (hint 4) re-jitted nothing.
        # (Bucket 8 is step 0's conservative full-batch-width compact fn.)
        assert all(v == 1 for v in exc.trace_counts.values())
        traced_buckets = sorted(
            b for (_spec, b) in exc.trace_counts if b is not None
        )
        assert traced_buckets == [1, 4, 8]

    def test_compaction_off_is_legacy(self, deep_model):
        cfg, params = deep_model
        exm, outs = _run(cfg, params, (2,), batch=4, steps=2, compaction="off")
        assert exm.overflow_retries == 0
        assert all(c.bucket == 4 for r in outs for c in r.compaction)


class TestBucketedCostModel:
    def test_bucketed_at_least_ideal_and_exact_at_zero_exit(self):
        t_c = np.array([0.0, 0.01, 0.01, 0.01, 0.01])
        alpha = np.full(5, 1e4)
        tiers = [TierSpec("e", 20.0, 1e7), TierSpec("c", 1.0)]
        p = np.array([0.0, 0.6, 0.0, 0.5, 0.0])
        ideal = expected_time_multitier(t_c, alpha, p, tiers, (2,))
        buck = expected_time_multitier(t_c, alpha, p, tiers, (2,), batch=8)
        assert buck >= ideal - 1e-12  # padding waste never helps
        p0 = np.zeros(5)
        a = expected_time_multitier(t_c, alpha, p0, tiers, (2,))
        b = expected_time_multitier(t_c, alpha, p0, tiers, (2,), batch=8)
        assert a == pytest.approx(b, rel=1e-12)

    def test_padding_waste_shrinks_with_batch(self):
        """Bigger batches amortize bucket rounding: the bucketed cost
        approaches the full-batch-entry ideal from above."""
        t_c = np.array([0.0, 0.01, 0.01, 0.01, 0.01])
        alpha = np.full(5, 1e4)
        tiers = [TierSpec("e", 20.0, 1e7), TierSpec("c", 1.0)]
        p = np.array([0.0, 0.55, 0.0, 0.0, 0.0])
        costs = [
            expected_time_multitier(t_c, alpha, p, tiers, (2,), batch=b)
            for b in (4, 64, 4096)
        ]
        assert costs[0] >= costs[1] >= costs[2] - 1e-12

    def test_bucketed_solver_returns_valid_plan(self):
        rng = np.random.default_rng(3)
        tiers = [TierSpec("d", 100.0, 1e6), TierSpec("e", 10.0, 1e7),
                 TierSpec("c", 1.0)]
        for _ in range(20):
            n = int(rng.integers(2, 9))
            t_c = np.concatenate([[0.0], rng.uniform(1e-4, 1e-1, n)])
            alpha = rng.uniform(1e2, 1e6, n + 1)
            p = np.zeros(n + 1)
            p[1] = rng.uniform(0, 1)
            plan = solve_multitier(t_c, alpha, p, tiers, batch=16)
            assert len(plan.cut_after) == 2
            assert 0 <= plan.cut_after[0] <= plan.cut_after[1] <= n
            # The solver's optimum is achievable by some fixed-cut cost.
            best = min(
                expected_time_multitier(t_c, alpha, p, tiers, (s1, s2),
                                        batch=16)
                for s1 in range(n + 1) for s2 in range(s1, n + 1)
            )
            # Pointwise-vs-entry-frozen padding means the DP may differ
            # from the exact fixed-cut minimum, but never by more than the
            # padding of one bucket step (factor 2 on downstream compute).
            assert plan.expected_time_s <= best + 1e-12 or (
                plan.expected_time_s <= 2 * best
            )


class TestSimulatedNetwork:
    def test_step_wall_clock_reflects_uplink(self, deep_model):
        cfg, params = deep_model
        # ~8 KiB residual payload at d_model x 2 bytes x 4 rows; pick a
        # bandwidth that makes the transfer ~40 ms.
        per_seq = cfg.d_model * 2.0
        bw = per_seq * 4 * 8.0 / 0.04
        srv = PartitionedServer(
            cfg, params, 2,
            network=NetworkProfile("slow", bw),
            simulate_network=True,
            compaction="off",
        )
        caches = M.init_caches(cfg, 4, 32)
        tok = _toks(cfg, 4)
        rep, caches = srv.step(tok, 0, caches)  # warm the jit
        t0 = time.perf_counter()
        rep, caches = srv.step(tok, 1, caches)
        dt = time.perf_counter() - t0
        expected = rep.bytes_shipped * 8.0 / bw
        assert rep.sim_transfer_s == (pytest.approx(expected),)
        if rep.shipped:
            assert dt >= 0.9 * expected

    def test_no_simulation_by_default(self, deep_model):
        cfg, params = deep_model
        srv = PartitionedServer(cfg, params, 2,
                                network=NetworkProfile("fast", 1e9))
        rep, _ = srv.step(_toks(cfg, 4), 0, M.init_caches(cfg, 4, 32))
        assert rep.sim_transfer_s == ()


class TestDriftController:
    def _profile(self, cfg, p_k):
        costs = [LayerCost(f"l{i}", 0, 0, cfg.d_model * 2.0, 1e-3)
                 for i in range(cfg.num_layers)]
        return build_cost_profile(
            costs, cfg.branch_layers, p_k, "3g", 50.0, 64.0
        )

    def test_kl_zero_on_identical_distributions(self):
        p = np.array([0.3, 0.2])
        assert exit_drift_kl(p, p) == pytest.approx(0.0, abs=1e-9)
        assert exit_drift_kl(np.array([0.9, 0.0]), np.array([0.0, 0.0])) > 0.1

    def test_observe_accumulates_and_triggers_on_drift(self, deep_model):
        cfg, params = deep_model
        profile = self._profile(cfg, np.array([0.1, 0.1]))
        srv = PartitionedServer(cfg, params, 2, cost_profile=profile,
                                network=NetworkProfile("3g", 1.1e6))
        ctl = RepartitionController(
            srv, profile, kl_threshold=0.05, every_n_steps=2
        )
        ctl._install(np.array([0.1, 0.1]))  # plan solved for mild exits

        class FakeReport:
            def __init__(self, batch, takes):
                self.tokens = np.zeros(batch, np.int64)
                self.branch_take = takes

        # Matching traffic: no swap on the every-N check.
        b = 10
        match = {1: np.zeros(b, bool), 3: np.zeros(b, bool)}
        match[1][:1] = True  # ~0.1 conditional at branch 1
        match[3][1:2] = True  # ~0.11 at branch 3
        swaps = [ctl.observe(FakeReport(b, match)) for _ in range(2)]
        assert swaps[0] is None  # cadence not reached
        assert ctl.drift_kl() < 0.05
        assert swaps[1] is None  # checked, below threshold

        # Drifted traffic: nearly everything exits at branch 1.  The
        # every-N check must fire a re-solve once, after which the
        # installed distribution tracks the measured one (drift ~ 0).
        drift = {1: np.ones(b, bool), 3: np.zeros(b, bool)}
        swaps = [ctl.observe(FakeReport(b, drift)) for _ in range(40)]
        assert any(s is not None for s in swaps)
        # A swap resets the measurement window; feed a little more traffic
        # and confirm we are re-anchored on the new regime and can force.
        ctl.observe(FakeReport(b, drift))
        assert ctl.drift_kl() < 0.05
        assert ctl.maybe_update(force=True) is not None

    def test_update_network_reinstalls(self, deep_model):
        cfg, params = deep_model
        profile = self._profile(cfg, np.array([0.2, 0.2]))
        srv = PartitionedServer(cfg, params, 0, cost_profile=profile,
                                network=NetworkProfile("wifi", 18.8e6))
        ctl = RepartitionController(srv, profile)
        ctl._install(np.array([0.2, 0.2]))
        cuts = ctl.update_network(NetworkProfile("3g", 0.4e6))
        assert len(cuts) == 1 and 0 <= cuts[0] <= cfg.num_layers
        assert srv.network.bandwidth_bps == 0.4e6
        # The executor's installed segments carry the new uplink.
        edge = srv.executor.segments[0]
        if not edge.is_empty and len(srv.executor.segments) > 1:
            assert edge.uplink_bps == 0.4e6

    def test_update_tiers_multitier(self, deep_model):
        cfg, params = deep_model
        profile = self._profile(cfg, np.array([0.2, 0.2]))
        tiers = [TierSpec("d", 50.0, 1e6), TierSpec("e", 10.0, 1e7),
                 TierSpec("c", 1.0)]
        srv = MultiTierServer(cfg, params, tiers, (1, 2),
                              cost=(profile.t_c, profile.alpha))
        ctl = RepartitionController(srv, profile, tiers, batch=8)
        new_tiers = [TierSpec("d", 50.0, 5e5), TierSpec("e", 10.0, 5e6),
                     TierSpec("c", 1.0)]
        cuts = ctl.update_tiers(new_tiers)
        assert len(cuts) == 2 and cuts[0] <= cuts[1] <= cfg.num_layers
        assert srv.tiers[0].uplink_bps == 5e5
        rep, _ = srv.step(_toks(cfg, 4), 0, M.init_caches(cfg, 4, 32))
        assert rep.tokens.shape == (4,)


class TestPipelinedCompaction:
    """overlap="pipelined" composed with survivor compaction: bitwise
    equivalence to the masked serial path across K, the overflow-retry
    serial fallback, and the steady-state wall-clock win."""

    def _run(self, cfg, params, cuts, *, compaction, overlap, steps,
             batch=8):
        # Fast uplinks: microsecond sleeps, so equivalence tests stay quick.
        uplinks = (1e9,) * len(cuts)
        ex = TierExecutor(
            cfg, params, segments_for_cuts(cfg, cuts, uplinks=uplinks),
            compaction=compaction, simulate_network=True, overlap=overlap,
        )
        caches = M.init_caches(cfg, batch, 64)
        tok = _toks(cfg, batch)
        out = []
        for i in range(steps):
            res, caches = ex.step(tok, i, caches)
            out.append(res)
            tok = res.tokens_dev[:, None]
        ex.drain()
        return ex, out

    @pytest.mark.parametrize("cuts", [(2,), (2, 3)])
    def test_bucketed_pipelined_matches_bucketed_serial(self, deep_model, cuts):
        """Pipelining composes with compaction without touching the
        trajectory: bucketed+pipelined is bitwise equal to bucketed+serial
        on every step (and both match the masked path on the first step,
        before the documented exited-row KV-hole divergence can appear)."""
        cfg0, params = deep_model
        cfg = dataclasses.replace(
            cfg0, exit_threshold=_mixed_threshold(cfg0, params)
        )
        _, outs_m = self._run(cfg, params, cuts, compaction="off",
                              overlap="serial", steps=1)
        _, outs_s = self._run(cfg, params, cuts, compaction="bucketed",
                              overlap="serial", steps=4)
        exp, outs_p = self._run(cfg, params, cuts, compaction="bucketed",
                                overlap="pipelined", steps=4)
        np.testing.assert_array_equal(outs_m[0].tokens, outs_p[0].tokens)
        np.testing.assert_array_equal(outs_m[0].exited, outs_p[0].exited)
        saw_exit = False
        for a, b in zip(outs_s, outs_p):
            saw_exit |= bool(a.exited.any())
            np.testing.assert_array_equal(a.tokens, b.tokens)
            np.testing.assert_array_equal(a.exited, b.exited)
            assert a.shipped_per_hop == b.shipped_per_hop
            assert a.bytes_per_hop == b.bytes_per_hop
            for c, d in zip(a.compaction, b.compaction):
                assert c == d
        assert saw_exit  # the mix regime really exercised compaction
        # The 1-sync invariant survives overlap: exactly one fetch per
        # step, plus one per (counted) overflow-retry iteration.
        assert exp.host_syncs == 4 + exp.overflow_retries

    def test_overflow_retry_falls_back_to_serial(self, deep_model):
        """An overflow-retry step in pipelined mode drains the pipeline and
        pays its transfers inline (counted in pipeline_fallbacks); tokens
        stay bitwise identical to the masked serial path."""
        cfg0, params = deep_model
        cfg = dataclasses.replace(cfg0, exit_threshold=0.0)  # no exits
        exm = TierExecutor(cfg, params, segments_for_cuts(cfg, (2,)),
                           compaction="off")
        exc = TierExecutor(
            cfg, params,
            segments_for_cuts(cfg, (2,), uplinks=(1e9,)),
            simulate_network=True, overlap="pipelined",
        )
        cm, cc = M.init_caches(cfg, 8, 32), M.init_caches(cfg, 8, 32)
        tok = _toks(cfg, 8)
        rm, cm = exm.step(tok, 0, cm)
        rc, cc = exc.step(tok, 0, cc)
        np.testing.assert_array_equal(rm.tokens, rc.tokens)
        exc._hints = {1: 1}  # stale all-exit hint: 8 survivors arrive
        rm, cm = exm.step(rm.tokens_dev[:, None], 1, cm)
        rc, cc = exc.step(rc.tokens_dev[:, None], 1, cc)
        np.testing.assert_array_equal(rm.tokens, rc.tokens)
        np.testing.assert_array_equal(rm.exited, rc.exited)
        assert exc.overflow_retries == 1
        assert exc.pipeline_fallbacks == 1
        assert exc._link_free == []  # the fallback drained the pipeline
        # Pipelining resumes on the next (non-retry) step.
        rm, cm = exm.step(rm.tokens_dev[:, None], 2, cm)
        rc, cc = exc.step(rc.tokens_dev[:, None], 2, cc)
        np.testing.assert_array_equal(rm.tokens, rc.tokens)
        assert exc.pipeline_fallbacks == 1

    def test_pipelined_steady_state_beats_serial_sum(self, deep_model):
        """Transfer-dominated K=3: serial pays compute + sum of hops per
        step, pipelined pays ~max(compute, bottleneck hop)."""
        cfg0, params = deep_model
        cfg = dataclasses.replace(cfg0, exit_threshold=0.0)  # all ship
        batch = 4
        per_seq = cfg.d_model * 2.0
        uplinks = tuple(
            per_seq * batch * 8.0 / s for s in (0.04, 0.025)
        )
        times = {}
        for overlap in ("serial", "pipelined"):
            ex = TierExecutor(
                cfg, params,
                segments_for_cuts(cfg, (2, 3), uplinks=uplinks),
                compaction="off", simulate_network=True, overlap=overlap,
            )
            caches = M.init_caches(cfg, batch, 64)
            tok = _toks(cfg, batch)
            res, caches = ex.step(tok, 0, caches)  # warm the jit
            ex.drain()
            t0 = time.perf_counter()
            for i in range(1, 5):
                res, caches = ex.step(res.tokens_dev[:, None], i, caches)
            ex.drain()
            times[overlap] = (time.perf_counter() - t0) / 4
            assert res.sim_transfer_s == (
                pytest.approx(0.04), pytest.approx(0.025)
            )
        # Serial sleeps 65 ms/step; pipelined ~40 ms (bottleneck hop) plus
        # a one-step pipeline-fill tail amortized over 4 steps.  Their
        # computes are identical, so a 10 ms margin is comfortable.
        assert times["pipelined"] < times["serial"] - 0.010


class TestServerPlumbing:
    def test_partitioned_report_carries_compaction(self, deep_model):
        cfg0, params = deep_model
        cfg = dataclasses.replace(cfg0, exit_threshold=1.5)
        srv = PartitionedServer(cfg, params, 2)
        caches = M.init_caches(cfg, 4, 32)
        tok = _toks(cfg, 4)
        rep, caches = srv.step(tok, 0, caches)
        rep, caches = srv.step(tok, 1, caches)
        assert rep.compaction[0].survivors == 0
        assert rep.compaction[0].bucket == 1
        assert rep.compaction[0].padded_waste == 1
        assert set(rep.branch_take) == {1}

    def test_multitier_bucketed_estimate_counts_padding(self, deep_model):
        """With compaction on and exits live, the report's estimate uses
        the bucketed cost model (>= the ideal per-sample estimate)."""
        cfg0, params = deep_model
        cfg = dataclasses.replace(cfg0, exit_threshold=1.5)
        profile_tc = np.concatenate([[0.0], np.full(cfg.num_layers, 1e-3)])
        alpha = np.full(cfg.num_layers + 1, cfg.d_model * 2.0)
        tiers = [TierSpec("e", 25.0, 1e7), TierSpec("c", 1.0)]
        on = MultiTierServer(cfg, params, tiers, (2,),
                             cost=(profile_tc, alpha))
        off = MultiTierServer(cfg, params, tiers, (2,),
                              cost=(profile_tc, alpha), compaction="off")
        caches = M.init_caches(cfg, 8, 32)
        rep_on, _ = on.step(_toks(cfg, 8), 0, M.init_caches(cfg, 8, 32))
        rep_off, _ = off.step(_toks(cfg, 8), 0, caches)
        assert rep_on.est_latency_s >= rep_off.est_latency_s - 1e-12
