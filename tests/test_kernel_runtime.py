"""Kernel-backed decode hot path (``use_kernels``) through the tier
runtime, plus the bucket-hint policy and exploration satellites:

  * full TierExecutor decode trajectories with the Pallas kernels in
    interpret mode are token/exit-mask identical to the pure-jnp path —
    K in {1, 2, 3}, compaction on/off, bucket-boundary batches, GQA and
    Mamba2 (SSD) trunks — and keep exactly one host sync per step;
  * windowed-max bucket hints + the bucket_headroom knob
    (hint_window=1, headroom=0 reproduces last-step exact-fit);
  * overflow_retries / pipeline_fallbacks surfaced in both servers'
    reports;
  * probe steps: all-branch evaluation that never touches the
    trajectory, and the RepartitionController's explore_every_n epsilon
    schedule refreshing discarded-branch probabilities through it.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import LayerCost, NetworkProfile, build_cost_profile
from repro.core.multitier import TierSpec, bucket_for
from repro.models import model as M
from repro.serving import (
    MultiTierServer,
    PartitionedServer,
    RepartitionController,
    TierExecutor,
    segments_for_cuts,
)


@pytest.fixture(scope="module")
def deep_model():
    """4 trunk layers, branches after v_1 and v_3 (as in test_compaction),
    with a threshold calibrated to a mixed exit regime."""
    cfg = dataclasses.replace(
        get_smoke_config("phi3_mini_3_8b"), num_layers=4, branch_layers=(1, 3)
    )
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    ex = TierExecutor(cfg, params, segments_for_cuts(cfg, ()))
    res, _ = ex.step(_toks(cfg, 8), 0, M.init_caches(cfg, 8, 32))
    ents = np.concatenate([res.branch_entropy[l] for l in cfg.branch_layers])
    cfg = dataclasses.replace(
        cfg, exit_threshold=float((ents.min() + ents.max()) / 2)
    )
    return cfg, params


@pytest.fixture(scope="module")
def ssm_model():
    """Mamba2 smoke trunk with one side branch (SSD decode kernel path)."""
    cfg = dataclasses.replace(get_smoke_config("mamba2_130m"), branch_layers=(1,))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _toks(cfg, batch, seed=2):
    return jax.random.randint(
        jax.random.PRNGKey(seed), (batch, 1), 0, cfg.vocab_size
    )


def _run(cfg, params, cuts, *, batch, steps, use_kernels,
         compaction="bucketed"):
    ex = TierExecutor(
        cfg, params, segments_for_cuts(cfg, cuts),
        compaction=compaction, use_kernels=use_kernels,
    )
    caches = M.init_caches(cfg, batch, 64)
    tok = _toks(cfg, batch)
    out = []
    for i in range(steps):
        res, caches = ex.step(tok, i, caches)
        out.append(res)
        tok = res.tokens_dev[:, None]
    return ex, out


def _assert_same_trajectory(outs_a, outs_b):
    for a, b in zip(outs_a, outs_b):
        np.testing.assert_array_equal(a.tokens, b.tokens)
        np.testing.assert_array_equal(a.exited, b.exited)
        np.testing.assert_array_equal(a.exit_tier, b.exit_tier)
        assert a.shipped_per_hop == b.shipped_per_hop
        for layer in a.branch_take:
            np.testing.assert_array_equal(
                a.branch_take[layer], b.branch_take[layer]
            )


class TestKernelTrajectoryEquivalence:
    """use_kernels=True (interpret mode on CPU) vs the jnp path: identical
    tokens and exit masks over full decode trajectories, 1 sync/step."""

    @pytest.mark.parametrize("cuts", [(), (2,), (1, 3)])
    @pytest.mark.parametrize("compaction", ["bucketed", "off"])
    def test_gqa_trajectory_identical(self, deep_model, cuts, compaction):
        cfg, params = deep_model
        exj, outs_j = _run(cfg, params, cuts, batch=5, steps=3,
                           use_kernels=False, compaction=compaction)
        exk, outs_k = _run(cfg, params, cuts, batch=5, steps=3,
                           use_kernels=True, compaction=compaction)
        _assert_same_trajectory(outs_j, outs_k)
        # Entropies agree to fp32 reduction-order tolerance.
        for a, b in zip(outs_j, outs_k):
            for layer in a.branch_entropy:
                np.testing.assert_allclose(
                    a.branch_entropy[layer], b.branch_entropy[layer],
                    rtol=1e-5, atol=1e-5,
                )
        # The kernel path keeps the 1-sync-per-step contract.
        assert exk.host_syncs == 3 + exk.overflow_retries
        assert exk.use_kernels and not exj.use_kernels

    @pytest.mark.parametrize("batch", [1, 4])
    def test_bucket_boundary_batches(self, deep_model, batch):
        cfg, params = deep_model
        _, outs_j = _run(cfg, params, (2,), batch=batch, steps=3,
                         use_kernels=False)
        _, outs_k = _run(cfg, params, (2,), batch=batch, steps=3,
                         use_kernels=True)
        _assert_same_trajectory(outs_j, outs_k)

    def test_ssm_trajectory_identical(self, ssm_model):
        """Mamba2 decode runs the ssd_update kernel; trajectory matches."""
        cfg, params = ssm_model
        exj, outs_j = _run(cfg, params, (1,), batch=4, steps=3,
                           use_kernels=False)
        exk, outs_k = _run(cfg, params, (1,), batch=4, steps=3,
                           use_kernels=True)
        _assert_same_trajectory(outs_j, outs_k)
        assert exk.host_syncs == 3 + exk.overflow_retries

    def test_knob_resolution(self, deep_model):
        """None defers to cfg.use_kernels, then to the backend default."""
        cfg, params = deep_model
        segs = segments_for_cuts(cfg, ())
        assert not TierExecutor(cfg, params, segs).use_kernels  # CPU auto
        assert TierExecutor(cfg, params, segs, use_kernels=True).use_kernels
        cfg_on = dataclasses.replace(cfg, use_kernels=True)
        assert TierExecutor(cfg_on, params, segs).use_kernels
        # Constructor override beats the config.
        assert not TierExecutor(
            cfg_on, params, segs, use_kernels=False
        ).use_kernels


class TestBucketHintPolicy:
    def _executor(self, deep_model, **kw):
        cfg, params = deep_model
        return TierExecutor(cfg, params, segments_for_cuts(cfg, (2,)), **kw)

    def test_windowed_max(self, deep_model):
        """The effective hint is the max survivor count over the last
        hint_window observations — a burst keeps the bucket provisioned
        until it ages out."""
        ex = self._executor(deep_model, hint_window=3)
        for count in (5, 2, 1):
            ex._observe_hints({1: count})
        assert ex._hints == {1: 5}
        ex._observe_hints({1: 1})  # the 5 ages out of the 3-wide window
        assert ex._hints == {1: 2}
        assert ex._plan_buckets(8) == {1: bucket_for(2, 8)}

    def test_window_one_is_last_step_only(self, deep_model):
        """hint_window=1, headroom=0 reproduces the historical policy."""
        ex = self._executor(deep_model, hint_window=1)
        for count in (5, 2):
            ex._observe_hints({1: count})
        assert ex._hints == {1: 2}

    def test_headroom_inflates_bucket(self, deep_model):
        ex = self._executor(deep_model, bucket_headroom=0.5)
        ex._observe_hints({1: 3})
        # ceil(3 * 1.5) = 5 -> bucket 8 (ladder 1,2,4,8); exact fit gives 4.
        assert ex._plan_buckets(8) == {1: 8}
        ex0 = self._executor(deep_model)
        ex0._observe_hints({1: 3})
        assert ex0._plan_buckets(8) == {1: 4}

    def test_headroom_cuts_retries_under_fluctuation(self, deep_model):
        """A fluctuating exit rate that overflows exact-fit hints is
        absorbed by headroom (fewer overflow retries, same trajectory)."""
        cfg0, params = deep_model
        # Threshold below every entropy: nobody exits, so every step's
        # true survivor count is the full batch while we feed stale hints.
        cfg = dataclasses.replace(cfg0, exit_threshold=0.0)
        runs = {}
        for headroom in (0.0, 1.0):
            ex = TierExecutor(
                cfg, params, segments_for_cuts(cfg, (2,)),
                bucket_headroom=headroom,
            )
            caches = M.init_caches(cfg, 8, 32)
            tok = _toks(cfg, 8)
            res, caches = ex.step(tok, 0, caches)
            ex._hints = {1: 4}  # stale under-estimate; headroom doubles it
            res, caches = ex.step(res.tokens_dev[:, None], 1, caches)
            runs[headroom] = (ex.overflow_retries, res.tokens)
        assert runs[0.0][0] == 1  # exact fit: bucket 4 overflows, retries
        assert runs[1.0][0] == 0  # ceil(4*2)=8 fits the spike
        np.testing.assert_array_equal(runs[0.0][1], runs[1.0][1])

    def test_validation(self, deep_model):
        with pytest.raises(ValueError):
            self._executor(deep_model, hint_window=0)
        with pytest.raises(ValueError):
            self._executor(deep_model, bucket_headroom=-0.1)


class TestReportCounters:
    def test_partitioned_server_surfaces_counters(self, deep_model):
        cfg0, params = deep_model
        cfg = dataclasses.replace(cfg0, exit_threshold=0.0)  # no exits
        srv = PartitionedServer(cfg, params, 2)
        caches = M.init_caches(cfg, 8, 32)
        rep, caches = srv.step(_toks(cfg, 8), 0, caches)
        assert rep.overflow_retries == 0 and rep.pipeline_fallbacks == 0
        srv.executor._hints = {1: 1}  # stale all-exit hint: 8 arrive
        rep, caches = srv.step(rep.tokens[:, None], 1, caches)
        assert rep.overflow_retries == 1

    def test_multitier_server_surfaces_counters(self, deep_model):
        cfg0, params = deep_model
        cfg = dataclasses.replace(cfg0, exit_threshold=0.0)
        tiers = [TierSpec("edge", 2.0, 1e9), TierSpec("mid", 1.5, 1e9),
                 TierSpec("cloud", 1.0)]
        srv = MultiTierServer(cfg, params, tiers, (1, 3))
        caches = M.init_caches(cfg, 4, 32)
        rep, caches = srv.step(_toks(cfg, 4), 0, caches)
        assert rep.overflow_retries == 0 and rep.pipeline_fallbacks == 0


class TestProbeSteps:
    def test_probe_reports_all_branches_without_touching_trajectory(
        self, deep_model
    ):
        """A probed step emits identical tokens/exits/caches to a normal
        step but reports would-exit masks for every cfg.branch_layers —
        including branch 3, which the (2,) plan discards at the cloud."""
        cfg, params = deep_model
        exp = TierExecutor(cfg, params, segments_for_cuts(cfg, (2,)))
        exn = TierExecutor(cfg, params, segments_for_cuts(cfg, (2,)))
        cp, cn = M.init_caches(cfg, 8, 32), M.init_caches(cfg, 8, 32)
        tok = _toks(cfg, 8)
        exp.probe_next = True
        rp, cp = exp.step(tok, 0, cp)
        rn, cn = exn.step(tok, 0, cn)
        np.testing.assert_array_equal(rp.tokens, rn.tokens)
        np.testing.assert_array_equal(rp.exited, rn.exited)
        np.testing.assert_array_equal(rp.exit_tier, rn.exit_tier)
        assert sorted(rn.branch_take) == [1]  # plan evaluates branch 1 only
        assert sorted(rp.branch_take) == [1, 3]  # probe adds the discarded
        assert 3 in rp.branch_entropy
        # The flag is one-shot: the following step is a normal one...
        rp2, cp = exp.step(rp.tokens_dev[:, None], 1, cp)
        rn2, cn = exn.step(rn.tokens_dev[:, None], 1, cn)
        assert sorted(rp2.branch_take) == [1]
        # ... and bitwise unaffected by the probe before it.
        np.testing.assert_array_equal(rp2.tokens, rn2.tokens)

    def test_probe_with_kernels(self, deep_model):
        cfg, params = deep_model
        ex = TierExecutor(cfg, params, segments_for_cuts(cfg, (2,)),
                          use_kernels=True)
        exn = TierExecutor(cfg, params, segments_for_cuts(cfg, (2,)))
        ex.probe_next = True
        rp, _ = ex.step(_toks(cfg, 8), 0, M.init_caches(cfg, 8, 32))
        rn, _ = exn.step(_toks(cfg, 8), 0, M.init_caches(cfg, 8, 32))
        np.testing.assert_array_equal(rp.tokens, rn.tokens)
        assert sorted(rp.branch_take) == [1, 3]

    def test_controller_explore_refreshes_discarded_branch(self, deep_model):
        """explore_every_n epsilon schedule: the probed step's report gives
        the discarded branch measured arrivals, so measured_probs() stops
        carrying the installed estimate for it."""
        cfg, params = deep_model
        costs = [LayerCost(f"l{i}", 0, 0, cfg.d_model * 2.0, 1e-3)
                 for i in range(cfg.num_layers)]
        profile = build_cost_profile(
            costs, cfg.branch_layers, np.array([0.3, 0.7]), "3g", 50.0, 64.0
        )
        srv = PartitionedServer(cfg, params, 2, cost_profile=profile,
                                network=NetworkProfile("3g", 1.1e6))
        ctl = RepartitionController(srv, profile, explore_every_n=2)
        ctl._installed_p = np.array([0.3, 0.7])
        caches = M.init_caches(cfg, 8, 32)
        tok = _toks(cfg, 8)
        pos = 0
        saw_probe = False
        for _ in range(4):
            rep, caches = srv.step(tok, pos, caches)
            saw_probe |= 3 in rep.branch_take
            ctl.observe(rep)
            tok, pos = rep.tokens[:, None], pos + 1
        assert saw_probe  # the schedule really probed
        assert ctl._arrivals[1] > 0  # discarded branch observed arrivals
        measured = ctl.measured_probs()
        # Branch 3's probability is now measured, not the installed 0.7
        # carry-over (the fixed seed's mixed regime never exits everyone).
        assert measured[1] != pytest.approx(0.7)

    def test_observe_conditional_accounting_with_probed_early_branch(
        self, deep_model
    ):
        """Regression: a probed (discarded) branch ordered BEFORE a kept
        branch removes its would-exit rows from the controller's alive
        mask, while the later branch's take (computed under plan
        semantics) can still contain them — exits must be intersected
        with alive or the conditional estimate exceeds 1."""
        cfg, params = deep_model
        costs = [LayerCost(f"l{i}", 0, 0, cfg.d_model * 2.0, 1e-3)
                 for i in range(cfg.num_layers)]
        profile = build_cost_profile(
            costs, cfg.branch_layers, np.array([0.3, 0.3]), "3g", 50.0, 64.0
        )
        srv = PartitionedServer(cfg, params, 2, cost_profile=profile,
                                network=NetworkProfile("3g", 1.1e6))
        ctl = RepartitionController(srv, profile)

        class FakeReport:
            def __init__(self, batch, takes):
                self.tokens = np.zeros(batch, np.int64)
                self.branch_take = takes

        # Probe-style report: rows 0 and 1 would exit at branch 1 AND are
        # marked taken at branch 3 (plan semantics never saw branch 1).
        takes = {
            1: np.array([True, True, False, False]),
            3: np.array([True, True, True, False]),
        }
        ctl.observe(FakeReport(4, takes))
        # Branch 1: 4 arrivals, 2 exits.  Branch 3: rows 2,3 arrive, only
        # row 2 exits among them (rows 0,1 already left at branch 1).
        np.testing.assert_allclose(ctl._arrivals, [4.0, 2.0])
        np.testing.assert_allclose(ctl._exits, [2.0, 1.0])
        measured = ctl.measured_probs()
        assert np.all(measured <= 1.0)
        np.testing.assert_allclose(measured, [0.5, 0.5])

    def test_controller_without_exploration_carries_installed(self, deep_model):
        cfg, params = deep_model
        costs = [LayerCost(f"l{i}", 0, 0, cfg.d_model * 2.0, 1e-3)
                 for i in range(cfg.num_layers)]
        profile = build_cost_profile(
            costs, cfg.branch_layers, np.array([0.3, 0.7]), "3g", 50.0, 64.0
        )
        srv = PartitionedServer(cfg, params, 2, cost_profile=profile,
                                network=NetworkProfile("3g", 1.1e6))
        ctl = RepartitionController(srv, profile)  # explore_every_n=0
        ctl._installed_p = np.array([0.3, 0.7])
        caches = M.init_caches(cfg, 8, 32)
        rep, caches = srv.step(_toks(cfg, 8), 0, caches)
        ctl.observe(rep)
        assert ctl._arrivals[1] == 0  # branch 3 never evaluated
        assert ctl.measured_probs()[1] == pytest.approx(0.7)  # carried
