"""DAG min-cut partitioner (paper future work, DESIGN.md Sec. 7).

Key property: on a chain with no branches, min-cut == shortest path.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import BranchSpec, CostProfile, NetworkProfile, brute_force_split
from repro.core.dag import DagCostModel, DagNode, chain_as_dag, min_cut_partition


class TestChainEquivalence:
    @settings(max_examples=60, deadline=None)
    @given(
        n=st.integers(2, 10),
        gamma=st.floats(1.0, 500.0),
        bw=st.floats(1e5, 1e9),
        seed=st.integers(0, 2**16),
    )
    def test_mincut_equals_shortest_path_on_chains(self, n, gamma, bw, seed):
        rng = np.random.default_rng(seed)
        t_c = np.concatenate([[0.0], rng.uniform(1e-4, 1e-1, n)])
        alpha = rng.uniform(1e2, 1e6, n + 1)
        prof = CostProfile(
            t_c=t_c, alpha=alpha, branches=(),
            gamma=gamma, network=NetworkProfile("t", bw),
        )
        sp = brute_force_split(prof)

        dag = chain_as_dag(t_c, alpha, bw, gamma)
        edge, cloud, cost = min_cut_partition(dag)
        assert cost == pytest.approx(sp.expected_time_s, rel=1e-6, abs=1e-9)
        # The cut encodes the same contiguous split.
        assert len(edge) == sp.split_layer

    def test_branchy_dag_with_two_paths(self):
        """A diamond DAG: input -> a -> {b, c} -> d.  With a fat b->d tensor
        and a slow edge, the cut should place d (and what it needs) in the
        cloud only when bandwidth makes that cheaper."""
        def build(bw):
            nodes = {
                "a": DagNode("a", 10e-3, 1e-3),
                "b": DagNode("b", 50e-3, 5e-3),
                "c": DagNode("c", 50e-3, 5e-3),
                "d": DagNode("d", 20e-3, 2e-3),
            }
            tx = 1e6 * 8 / bw
            links = [
                ("a", "b", tx), ("a", "c", tx),
                ("b", "d", tx), ("c", "d", tx),
            ]
            return DagCostModel(nodes, links, input_upload_time=4e6 * 8 / bw,
                                input_consumers=("a",))

        # Fast network: everything cloud (edge is 10x slower).
        edge, cloud, cost_fast = min_cut_partition(build(1e10))
        assert edge == set()
        # Very slow network: everything edge.
        edge, cloud, cost_slow = min_cut_partition(build(1e3))
        assert cloud == set()
        # Mid: a valid cut with no cloud->edge back-flow.
        edge, cloud, _ = min_cut_partition(build(2e8))
        for u, v, _tx in build(2e8).links:
            assert not (u in cloud and v in edge), "illegal cloud->edge flow"

    def test_cost_is_minimal_vs_bruteforce(self):
        """Exhaustive check on a small random DAG."""
        rng = np.random.default_rng(3)
        names = ["a", "b", "c", "d", "e"]
        nodes = {
            n: DagNode(n, float(rng.uniform(1e-3, 1e-1)),
                       float(rng.uniform(1e-4, 1e-2)))
            for n in names
        }
        links = [
            ("a", "b", float(rng.uniform(1e-4, 5e-2))),
            ("a", "c", float(rng.uniform(1e-4, 5e-2))),
            ("b", "d", float(rng.uniform(1e-4, 5e-2))),
            ("c", "d", float(rng.uniform(1e-4, 5e-2))),
            ("d", "e", float(rng.uniform(1e-4, 5e-2))),
        ]
        model = DagCostModel(nodes, links, input_upload_time=0.05,
                             input_consumers=("a",))
        _, _, cost = min_cut_partition(model)

        # Brute force over all downward-closed cloud sets.
        best = np.inf
        for mask in range(2 ** len(names)):
            cloud = {n for i, n in enumerate(names) if mask >> i & 1}
            # legality: no cloud -> edge dependency
            if any(u in cloud and v not in cloud for u, v, _ in links):
                continue
            c = sum(nodes[n].t_cloud if n in cloud else nodes[n].t_edge
                    for n in names)
            c += sum(tx for u, v, tx in links if u not in cloud and v in cloud)
            if "a" in cloud:
                c += model.input_upload_time
            best = min(best, c)
        assert cost == pytest.approx(best, rel=1e-6)
