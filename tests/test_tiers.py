"""Unified K-tier runtime (serving/tiers.py): segment planning rules,
tier-count equivalences (K=1 engine vs monolithic decode, K=2 MultiTier vs
PartitionedServer), single-host-sync invariant, per-hop byte accounting,
the repartition controller's no-re-jit hot swap, the pipelined overlap
mode (bitwise equivalence + bottleneck cost model + plan flip), and the
latency-estimator regressions (per-branch conditional probs, zero-uplink
transfer guard, degenerate-profile solver diagnostic)."""

import dataclasses
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import (
    BranchSpec,
    CostProfile,
    LayerCost,
    NetworkProfile,
    build_cost_profile,
    shortest_path_plan,
)
from repro.core.latency import expected_time
from repro.core.multitier import TierSpec, expected_time_multitier, solve_multitier
from repro.models import model as M
from repro.serving import (
    MultiTierServer,
    PartitionedServer,
    RepartitionController,
    ServingEngine,
    TierExecutor,
    segments_for_cuts,
)


@pytest.fixture(scope="module")
def deep_model():
    """4 trunk layers, branches after v_1 and v_3 — enough structure for
    K=3 cuts and branch-at-cut cases."""
    cfg = dataclasses.replace(
        get_smoke_config("phi3_mini_3_8b"), num_layers=4, branch_layers=(1, 3)
    )
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _toks(cfg, batch=4, seed=2):
    return jax.random.randint(
        jax.random.PRNGKey(seed), (batch, 1), 0, cfg.vocab_size
    )


class TestSegmentPlanning:
    def test_two_tier_matches_paper_semantics(self, deep_model):
        cfg, _ = deep_model
        edge, cloud = segments_for_cuts(cfg, (3,))
        assert (edge.layer_lo, edge.layer_hi) == (0, 3)
        assert edge.branches == (1,)  # branch at the cut (3) is discarded
        assert (cloud.layer_lo, cloud.layer_hi) == (3, 4)
        assert cloud.branches == ()  # the cloud evaluates no branches

    def test_edge_only_keeps_all_branches(self, deep_model):
        cfg, _ = deep_model
        edge, cloud = segments_for_cuts(cfg, (4,))
        assert edge.branches == (1, 3)  # no cut -> deepest branch evaluated
        assert cloud.is_empty

    def test_single_tier_is_monolithic_branchynet(self, deep_model):
        cfg, _ = deep_model
        (seg,) = segments_for_cuts(cfg, ())
        assert (seg.layer_lo, seg.layer_hi) == (0, 4)
        assert seg.branches == cfg.branch_layers

    def test_three_tier_partition(self, deep_model):
        cfg, _ = deep_model
        d, e, c = segments_for_cuts(cfg, (1, 3))
        assert d.branches == ()  # branch 1 sits exactly at the first cut
        assert e.branches == ()  # branch 3 sits exactly at the second cut
        d, e, c = segments_for_cuts(cfg, (2, 4))
        assert d.branches == (1,) and e.branches == (3,) and c.is_empty

    def test_rejects_non_monotone_cuts(self, deep_model):
        cfg, _ = deep_model
        with pytest.raises(ValueError):
            segments_for_cuts(cfg, (3, 1))
        with pytest.raises(ValueError):
            segments_for_cuts(cfg, (5,))


def _random_chain(rng, n):
    """Random (t_c, alpha, p) chain with up to two branches (mirrors
    test_multitier.random_chain, hypothesis-free so it always runs)."""
    t_c = np.concatenate([[0.0], rng.uniform(1e-4, 1e-1, n)])
    alpha = rng.uniform(1e2, 1e6, n + 1)
    p = np.zeros(n + 1)
    if n > 2:
        for i in rng.choice(np.arange(1, n), size=min(2, n - 1), replace=False):
            p[i] = rng.uniform(0, 1)
    return t_c, alpha, p


class TestSolverEquivalence:
    """Plain seeded randomized sweeps (no hypothesis dependency) so these
    run in every tier-1 environment."""

    def test_k2_cuts_match_dijkstra(self):
        """The lattice DP at K=2 lands on the same cut (and E[T]) as the
        paper's Dijkstra run over G'_BDNN."""
        rng = np.random.default_rng(7)
        for _ in range(60):
            n = int(rng.integers(2, 13))
            gamma = float(rng.uniform(1.0, 500.0))
            bw = float(rng.uniform(1e5, 1e9))
            t_c, alpha, p = _random_chain(rng, n)
            plan = solve_multitier(
                t_c, alpha, p,
                [TierSpec("edge", gamma, bw), TierSpec("cloud", 1.0)],
            )
            branches = tuple(
                BranchSpec(i, float(p[i])) for i in range(1, n) if p[i] > 0
            )
            prof = CostProfile(
                t_c=t_c, alpha=alpha, branches=branches, gamma=gamma,
                network=NetworkProfile("t", bw),
            )
            ref = shortest_path_plan(prof)
            assert plan.expected_time_s == pytest.approx(
                ref.expected_time_s, rel=1e-9, abs=1e-12
            )
            assert plan.cut_after == (ref.split_layer,)

    def test_solver_optimum_is_min_over_fixed_cuts(self):
        """solve_multitier's E[T] equals the minimum of the closed-form
        fixed-cut cost (the estimate the runtime reports) over all cuts."""
        rng = np.random.default_rng(11)
        tiers = [TierSpec("device", 200.0, 1e6), TierSpec("edge", 20.0, 2e7),
                 TierSpec("cloud", 1.0)]
        for _ in range(40):
            n = int(rng.integers(2, 9))
            t_c, alpha, p = _random_chain(rng, n)
            plan = solve_multitier(t_c, alpha, p, tiers)
            best = min(
                expected_time_multitier(t_c, alpha, p, tiers, (s1, s2))
                for s1 in range(n + 1) for s2 in range(s1, n + 1)
            )
            assert plan.expected_time_s == pytest.approx(
                best, rel=1e-9, abs=1e-12
            )
            assert expected_time_multitier(
                t_c, alpha, p, tiers, plan.cut_after
            ) == pytest.approx(plan.expected_time_s, rel=1e-9, abs=1e-12)


class TestOverlapCostModel:
    """expected_time_multitier(overlap=True): the pipelined steady-state
    step cost is the bottleneck stage, not the serial sum."""

    def test_overlap_is_bottleneck_stage(self):
        t_c = np.array([0.0, 0.01, 0.01, 0.01, 0.01])
        alpha = np.full(5, 1e5)
        p = np.zeros(5)
        tiers = [TierSpec("edge", 3.0, 2e6), TierSpec("cloud", 1.0)]
        s = 2
        edge = 3.0 * 0.02  # 2 layers at gamma 3
        xfer = 1e5 * 8.0 / 2e6
        cloud = 0.02
        serial = expected_time_multitier(t_c, alpha, p, tiers, (s,))
        ovl = expected_time_multitier(t_c, alpha, p, tiers, (s,),
                                      overlap=True)
        assert serial == pytest.approx(edge + xfer + cloud)
        assert ovl == pytest.approx(max(edge, xfer, cloud))

    def test_overlap_never_exceeds_serial(self):
        """max of non-negative stages <= their sum, for every cut vector,
        branch regime, and bucketed/ideal weighting."""
        rng = np.random.default_rng(13)
        tiers = [TierSpec("d", 200.0, 1e6), TierSpec("e", 20.0, 2e7),
                 TierSpec("c", 1.0)]
        for _ in range(30):
            n = int(rng.integers(2, 9))
            t_c, alpha, p = _random_chain(rng, n)
            for batch in (None, 8):
                for s1 in range(n + 1):
                    for s2 in range(s1, n + 1):
                        ser = expected_time_multitier(
                            t_c, alpha, p, tiers, (s1, s2), batch=batch
                        )
                        ovl = expected_time_multitier(
                            t_c, alpha, p, tiers, (s1, s2), batch=batch,
                            overlap=True,
                        )
                        assert ovl <= ser + 1e-12

    def test_overlap_solver_matches_enumeration(self):
        rng = np.random.default_rng(17)
        tiers = [TierSpec("d", 100.0, 1e6), TierSpec("e", 10.0, 1e7),
                 TierSpec("c", 1.0)]
        for _ in range(25):
            n = int(rng.integers(2, 9))
            t_c, alpha, p = _random_chain(rng, n)
            plan = solve_multitier(t_c, alpha, p, tiers, overlap=True)
            best = min(
                expected_time_multitier(t_c, alpha, p, tiers, (s1, s2),
                                        overlap=True)
                for s1 in range(n + 1) for s2 in range(s1, n + 1)
            )
            assert plan.expected_time_s == pytest.approx(
                best, rel=1e-9, abs=1e-12
            )
            assert expected_time_multitier(
                t_c, alpha, p, tiers, plan.cut_after, overlap=True
            ) == pytest.approx(plan.expected_time_s, rel=1e-9, abs=1e-12)

    def test_optimal_cut_moves_under_overlap(self):
        """The benchmark's plan-flip profile: transfers shrink with depth,
        so serial hides on the edge while overlap cuts early (a transfer
        below the bottleneck stage is free when pipelined)."""
        t_c = np.array([0.0, 0.01, 0.01, 0.01, 0.01])
        alpha = np.array([80e3, 40e3, 20e3, 10e3, 5e3])
        p = np.zeros(5)
        tiers = [TierSpec("edge", 2.0, 4e6), TierSpec("cloud", 1.0)]
        plan_s = solve_multitier(t_c, alpha, p, tiers)
        plan_o = solve_multitier(t_c, alpha, p, tiers, overlap=True)
        assert plan_s.cut_after == (4,)  # serial: ship nothing
        assert plan_o.cut_after == (2,)  # overlap: balance the stages
        assert plan_o.expected_time_s < plan_s.expected_time_s

    def test_degenerate_profile_raises_value_error(self):
        """An infeasible profile (unusable entry tier + zero uplink) gets a
        clear diagnostic instead of the historical UnboundLocalError."""
        t_c = np.array([0.0, 1.0])
        alpha = np.array([10.0, 10.0])
        p = np.zeros(2)
        tiers = [TierSpec("dev", np.inf, 0.0), TierSpec("cloud", 1.0)]
        with pytest.raises(ValueError, match="unreachable"):
            solve_multitier(t_c, alpha, p, tiers)

    def test_zero_uplink_with_feasible_edge_plan_solves(self):
        """A zero/unset uplink must not crash the solver when finishing on
        the reachable tiers is feasible (it simply prices the hop inf)."""
        t_c = np.array([0.0, 1.0, 1.0])
        alpha = np.array([10.0, 10.0, 10.0])
        plan = solve_multitier(
            t_c, alpha, np.zeros(3),
            [TierSpec("edge", 2.0, 0.0), TierSpec("cloud", 1.0)],
        )
        assert plan.cut_after == (2,)  # everything on the edge
        assert np.isfinite(plan.expected_time_s)


class TestTierEquivalence:
    """Identical computation regardless of how many tiers execute it."""

    @pytest.mark.parametrize("split", [0, 1, 2, 3, 4])
    def test_multitier_k2_matches_partitioned(self, deep_model, split):
        cfg, params = deep_model
        tok = _toks(cfg)
        p2 = PartitionedServer(cfg, params, split)
        k2 = MultiTierServer(
            cfg, params, [TierSpec("edge", 25.0, 5.85e6), TierSpec("cloud", 1.0)],
            (split,),
        )
        rep, _ = p2.step(tok, 0, M.init_caches(cfg, 4, 32))
        mrep, _ = k2.step(tok, 0, M.init_caches(cfg, 4, 32))
        np.testing.assert_array_equal(mrep.tokens, rep.tokens)
        np.testing.assert_array_equal(mrep.exited, rep.exited_on_edge)
        assert sum(mrep.shipped_per_hop) == rep.shipped
        assert sum(mrep.bytes_per_hop) == rep.bytes_shipped

    def test_multitier_k2_matches_partitioned_with_exits(self, deep_model):
        cfg, params = deep_model
        cfg = dataclasses.replace(cfg, exit_threshold=1.5)  # everyone exits
        tok = _toks(cfg)
        p2 = PartitionedServer(cfg, params, 2)
        k2 = MultiTierServer(
            cfg, params, [TierSpec("e", 25.0, 1e7), TierSpec("c", 1.0)], (2,)
        )
        rep, _ = p2.step(tok, 0, M.init_caches(cfg, 4, 32))
        mrep, _ = k2.step(tok, 0, M.init_caches(cfg, 4, 32))
        assert rep.exited_on_edge.all() and mrep.exited.all()
        np.testing.assert_array_equal(mrep.tokens, rep.tokens)
        assert mrep.shipped_per_hop == (0,) and rep.shipped == 0
        assert (mrep.exit_tier == 0).all()

    def test_k3_survivors_match_monolithic_tokens(self, deep_model):
        cfg, params = deep_model
        tok = _toks(cfg)
        caches = M.init_caches(cfg, 4, 32)
        mono = M.decode_step(params, tok, jnp.asarray(0, jnp.int32), caches, cfg)
        mono_tok = np.asarray(jnp.argmax(mono["logits"], -1))

        srv = MultiTierServer(
            cfg, params,
            [TierSpec("device", 200.0, 1e6), TierSpec("edge", 20.0, 2e7),
             TierSpec("cloud", 1.0)],
            (2, 3),
        )
        rep, _ = srv.step(tok, 0, M.init_caches(cfg, 4, 32))
        crossed = ~rep.exited
        np.testing.assert_array_equal(rep.tokens[crossed], mono_tok[crossed])

    def test_engine_matches_legacy_decode_loop(self, deep_model):
        """The fused device-resident exit masking reproduces the old
        per-branch host-round-trip loop token for token."""
        cfg, params = deep_model
        engine = ServingEngine(cfg, params, context_len=64)
        prompts = {"tokens": jax.random.randint(
            jax.random.PRNGKey(5), (3, 8), 0, cfg.vocab_size)}
        state = engine.start(prompts)
        toks, stats = engine.decode(state, steps=5)

        # Legacy loop: monolithic decode_step + host-side branch folding.
        state2 = engine.start(prompts)
        tok = jnp.argmax(state2["last_logits"], -1).astype(jnp.int32)[:, None]
        caches, pos = state2["caches"], state2["pos"]
        legacy = []
        counts = np.zeros(len(cfg.branch_layers) + 1, np.int64)
        for _ in range(5):
            out = M.decode_step(params, tok, jnp.asarray(pos, jnp.int32),
                                caches, cfg)
            caches, pos = out["caches"], pos + 1
            chosen = jnp.argmax(out["logits"], -1).astype(jnp.int32)
            exited = jnp.zeros(chosen.shape, bool)
            for j, layer in enumerate(cfg.branch_layers):
                b_tok = jnp.argmax(out["branch_logits"][layer], -1).astype(jnp.int32)
                take = out["branch_exit"][layer] & ~exited
                chosen = jnp.where(take, b_tok, chosen)
                counts[j] += int(np.asarray(take).sum())
                exited = exited | out["branch_exit"][layer]
            counts[-1] += int(np.asarray(~exited).sum())
            legacy.append(np.asarray(chosen))
            tok = chosen[:, None]
        np.testing.assert_array_equal(toks, np.stack(legacy, axis=1))
        np.testing.assert_array_equal(stats.counts, counts)


class TestHostSyncs:
    def test_one_sync_per_partitioned_step(self, deep_model):
        cfg, params = deep_model
        srv = PartitionedServer(cfg, params, 2)
        caches = M.init_caches(cfg, 4, 32)
        tok = _toks(cfg)
        for i in range(4):
            rep, caches = srv.step(tok, i, caches)
            tok = jnp.asarray(rep.tokens[:, None])
        assert srv.executor.host_syncs == 4

    def test_one_sync_per_engine_step(self, deep_model):
        cfg, params = deep_model
        engine = ServingEngine(cfg, params, context_len=64)
        state = engine.start({"tokens": jax.random.randint(
            jax.random.PRNGKey(8), (2, 4), 0, cfg.vocab_size)})
        engine.decode(state, steps=6)
        assert engine.host_syncs == 6


class TestByteAccounting:
    def test_k3_per_hop_bytes(self, deep_model):
        cfg, params = deep_model
        tiers = [TierSpec("d", 100.0, 1e6), TierSpec("e", 10.0, 1e7),
                 TierSpec("c", 1.0)]
        srv = MultiTierServer(cfg, params, tiers, (2, 3))
        rep, _ = srv.step(_toks(cfg), 0, M.init_caches(cfg, 4, 32))
        assert len(rep.shipped_per_hop) == 2
        per_seq = cfg.d_model * 2.0
        assert rep.bytes_per_hop == (
            rep.shipped_per_hop[0] * per_seq, rep.shipped_per_hop[1] * per_seq
        )
        # Survivors never resurrect across hops.
        assert rep.shipped_per_hop[0] >= rep.shipped_per_hop[1]
        # Transfer time is bytes over the *hop's own* uplink.
        assert rep.transfer_s_per_hop == (
            rep.bytes_per_hop[0] * 8.0 / tiers[0].uplink_bps,
            rep.bytes_per_hop[1] * 8.0 / tiers[1].uplink_bps,
        )

    def test_cloud_only_ships_token_ids(self, deep_model):
        cfg, params = deep_model
        srv = MultiTierServer(
            cfg, params, [TierSpec("e", 25.0, 1e7), TierSpec("c", 1.0)], (0,)
        )
        rep, _ = srv.step(_toks(cfg), 0, M.init_caches(cfg, 4, 32))
        assert rep.shipped_per_hop == (4,)
        assert rep.bytes_per_hop == (16.0,)  # 4 sequences * 4-byte token id

    def test_trailing_empty_tiers_ship_nothing(self, deep_model):
        cfg, params = deep_model
        srv = MultiTierServer(
            cfg, params,
            [TierSpec("d", 100.0, 1e6), TierSpec("e", 10.0, 1e7),
             TierSpec("c", 1.0)],
            (4, 4),
        )
        rep, _ = srv.step(_toks(cfg), 0, M.init_caches(cfg, 4, 32))
        assert rep.shipped_per_hop == () and rep.bytes_per_hop == ()


class TestPipelinedRuntime:
    """overlap="pipelined" is a wall-clock re-ordering of the simulated
    transfers only: tokens, exit masks, and per-hop byte accounting are
    bitwise identical to serial mode, and the one-fetch-per-emitted-token
    contract holds."""

    def _run(self, cfg, params, cuts, overlap, steps=3):
        # Fast uplinks: the simulated sleeps are microseconds, so the test
        # exercises the pipelined bookkeeping without slowing the suite.
        segs = segments_for_cuts(cfg, cuts, uplinks=(1e9,) * len(cuts))
        ex = TierExecutor(
            cfg, params, segs, compaction="off",
            simulate_network=True, overlap=overlap,
        )
        caches = M.init_caches(cfg, 4, 64)
        tok = _toks(cfg)
        out = []
        for i in range(steps):
            res, caches = ex.step(tok, i, caches)
            out.append(res)
            tok = res.tokens_dev[:, None]
        ex.drain()
        return ex, out

    @pytest.mark.parametrize("cuts", [(), (2,), (2, 3)])
    def test_bitwise_equivalent_to_serial(self, deep_model, cuts):
        cfg, params = deep_model
        exs, outs_s = self._run(cfg, params, cuts, "serial")
        exp, outs_p = self._run(cfg, params, cuts, "pipelined")
        for a, b in zip(outs_s, outs_p):
            np.testing.assert_array_equal(a.tokens, b.tokens)
            np.testing.assert_array_equal(a.exited, b.exited)
            np.testing.assert_array_equal(a.exit_tier, b.exit_tier)
            assert a.shipped_per_hop == b.shipped_per_hop
            assert a.bytes_per_hop == b.bytes_per_hop
            assert a.sim_transfer_s == b.sim_transfer_s
            for layer in a.branch_take:
                np.testing.assert_array_equal(
                    a.branch_take[layer], b.branch_take[layer]
                )
        # One fetch per emitted token on both paths.
        assert exs.host_syncs == exp.host_syncs == 3
        assert exp.pipeline_fallbacks == 0

    def test_drain_is_idempotent_and_resets(self, deep_model):
        cfg, params = deep_model
        ex, _ = self._run(cfg, params, (2,), "pipelined")
        assert ex._link_free == [] and ex._inflight_done == 0.0
        ex.drain()  # no-op when nothing is in flight
        assert ex._link_free == []

    def test_rejects_unknown_overlap_mode(self, deep_model):
        cfg, params = deep_model
        with pytest.raises(ValueError, match="overlap"):
            TierExecutor(
                cfg, params, segments_for_cuts(cfg, (2,)), overlap="async"
            )


class TestEstimatorRegressions:
    def test_partitioned_estimate_uses_conditional_probs(self, deep_model):
        """PartitionedServer._estimate historically substituted the
        *cumulative* measured exit fraction for every branch's conditional
        exit_prob, overestimating exits whenever two or more branches are
        evaluated.  With branch 1 exiting 4/8 and branch 3 exiting 2 of
        the 4 survivors, the conditionals are (0.5, 0.5) — not the 0.75
        cumulative fraction the old code installed at both branches."""
        cfg, params = deep_model
        costs = [LayerCost(f"l{i}", 0, 0, cfg.d_model * 2.0, 1e-3)
                 for i in range(cfg.num_layers)]
        profile = build_cost_profile(
            costs, cfg.branch_layers, np.array([0.3, 0.4]), "3g", 50.0, 64.0
        )
        srv = PartitionedServer(
            cfg, params, 4, cost_profile=profile, compaction="off"
        )
        take1 = np.zeros(8, bool)
        take1[:4] = True
        take3 = np.zeros(8, bool)
        take3[4:6] = True  # 2 of the 4 still alive after branch 1
        res = types.SimpleNamespace(
            tokens=np.zeros(8, np.int64), branch_take={1: take1, 3: take3}
        )
        est = srv._estimate(4, res)

        def at_probs(p1, p3):
            branches = tuple(
                dataclasses.replace(b, exit_prob={1: p1, 3: p3}[b.after_layer])
                for b in profile.branches
            )
            return expected_time(
                dataclasses.replace(profile, branches=branches), 4
            )

        assert est == pytest.approx(at_probs(0.5, 0.5))
        old_wrong = at_probs(0.75, 0.75)
        assert est != pytest.approx(old_wrong)
        # Inflated exits shed downstream compute -> the old estimate was
        # optimistic (too low).
        assert old_wrong < est

    def test_multitier_unset_uplink_reports_zero_transfer(self, deep_model):
        """TierSpec.uplink_bps defaults to 0.0: a plan whose hop bandwidth
        was never set must report 0.0 transfer time, not ZeroDivisionError
        (mirrors the executor's sim_transfer_s guard)."""
        cfg, params = deep_model
        srv = MultiTierServer(
            cfg, params, [TierSpec("e", 25.0), TierSpec("c", 1.0)], (2,)
        )
        rep, _ = srv.step(_toks(cfg), 0, M.init_caches(cfg, 4, 32))
        assert rep.transfer_s_per_hop == (0.0,)
        assert rep.tokens.shape == (4,)


class TestRepartition:
    def test_swap_reuses_unchanged_segments(self, deep_model):
        cfg, params = deep_model
        srv = MultiTierServer(
            cfg, params,
            [TierSpec("d", 100.0, 1e6), TierSpec("e", 10.0, 1e7),
             TierSpec("c", 1.0)],
            (1, 3),
        )
        cloud_fn = srv.executor.segment_fn(2)
        srv.install_cuts((2, 3))  # move only the first cut
        assert srv.executor.segment_fn(2) is cloud_fn  # cloud never re-jitted
        # Swapping back re-uses both previously compiled edge segments too.
        device_fn = srv.executor.segment_fn(0)
        srv.install_cuts((1, 3))
        srv.install_cuts((2, 3))
        assert srv.executor.segment_fn(0) is device_fn

    def test_controller_closes_the_loop(self, deep_model):
        cfg, params = deep_model
        engine = ServingEngine(cfg, params, context_len=64)
        state = engine.start({"tokens": jax.random.randint(
            jax.random.PRNGKey(9), (4, 6), 0, cfg.vocab_size)})
        _, stats = engine.decode(state, steps=3)

        costs = [LayerCost(f"l{i}", 0, 0, cfg.d_model * 2.0, 1e-3)
                 for i in range(cfg.num_layers)]
        profile = build_cost_profile(
            costs, cfg.branch_layers, stats.conditional_probs(), "3g", 50.0,
            64.0,
        )
        srv = PartitionedServer(cfg, params, 0, cost_profile=profile)
        ctl = RepartitionController(srv, profile)
        cuts = ctl.update(stats)
        assert len(cuts) == 1 and 0 <= cuts[0] <= cfg.num_layers
        assert srv.split_layer == cuts[0]
        rep, _ = srv.step(_toks(cfg), 0, M.init_caches(cfg, 4, 32))
        assert rep.tokens.shape == (4,)

    def test_controller_solves_overlap_for_pipelined_server(self, deep_model):
        """A pipelined server is re-solved against the bottleneck-stage
        cost: the controller's installed cut must minimize the overlap
        objective (which can differ from the serial Dijkstra cut)."""
        cfg, params = deep_model
        costs = [LayerCost(f"l{i}", 0, 0, cfg.d_model * 2.0, 1e-3)
                 for i in range(cfg.num_layers)]
        p_k = np.array([0.1, 0.1])
        profile = build_cost_profile(
            costs, cfg.branch_layers, p_k, "3g", 50.0, 64.0
        )
        srv = PartitionedServer(
            cfg, params, 0, cost_profile=profile,
            network=NetworkProfile("3g", 1.1e6), overlap="pipelined",
        )
        ctl = RepartitionController(srv, profile)
        (cut,) = ctl.solve(p_k)
        prof = dataclasses.replace(
            profile,
            branches=tuple(
                dataclasses.replace(b, exit_prob=float(p))
                for b, p in zip(profile.branches, p_k)
            ),
        )
        tiers = [TierSpec("edge", prof.gamma, prof.network.bandwidth_bps),
                 TierSpec("cloud", 1.0)]
        best = min(
            range(cfg.num_layers + 1),
            key=lambda s: expected_time_multitier(
                prof.t_c, prof.alpha, prof.branch_exit_probs(), tiers, (s,),
                overlap=True,
            ),
        )
        assert cut == best
        ctl._install(p_k)
        assert srv.split_layer == cut

    def test_controller_bucketed_2tier_solves_lattice_objective(self, deep_model):
        """With batch set and a compacting 2-tier server, solve() optimizes
        the same padding-honest bucketed lattice cost the server's
        est_latency_s reports — not the ideal Dijkstra sum."""
        cfg, params = deep_model
        costs = [LayerCost(f"l{i}", 0, 0, cfg.d_model * 2.0, 1e-3)
                 for i in range(cfg.num_layers)]
        p_k = np.array([0.6, 0.2])
        profile = build_cost_profile(
            costs, cfg.branch_layers, p_k, "3g", 50.0, 64.0
        )
        srv = PartitionedServer(
            cfg, params, 0, cost_profile=profile,
            network=NetworkProfile("3g", 1.1e6), compaction="bucketed",
        )
        ctl = RepartitionController(srv, profile, batch=8)
        (cut,) = ctl.solve(p_k)
        prof = dataclasses.replace(
            profile,
            branches=tuple(
                dataclasses.replace(b, exit_prob=float(p))
                for b, p in zip(profile.branches, p_k)
            ),
        )
        tiers = [TierSpec("edge", prof.gamma, prof.network.bandwidth_bps),
                 TierSpec("cloud", 1.0)]
        best = min(
            range(cfg.num_layers + 1),
            key=lambda s: expected_time_multitier(
                prof.t_c, prof.alpha, prof.branch_exit_probs(), tiers, (s,),
                batch=8,
            ),
        )
        assert cut == best

    def test_controller_multitier(self, deep_model):
        cfg, params = deep_model
        engine = ServingEngine(cfg, params, context_len=64)
        state = engine.start({"tokens": jax.random.randint(
            jax.random.PRNGKey(10), (4, 6), 0, cfg.vocab_size)})
        _, stats = engine.decode(state, steps=3)

        costs = [LayerCost(f"l{i}", 0, 0, cfg.d_model * 2.0, 1e-3)
                 for i in range(cfg.num_layers)]
        profile = build_cost_profile(
            costs, cfg.branch_layers, stats.conditional_probs(), "3g", 50.0,
            64.0,
        )
        tiers = [TierSpec("d", 50.0, 1e6), TierSpec("e", 10.0, 1e7),
                 TierSpec("c", 1.0)]
        srv = MultiTierServer(cfg, params, tiers, (0, 0),
                              cost=(profile.t_c, profile.alpha))
        ctl = RepartitionController(srv, profile, tiers)
        cuts = ctl.update(stats)
        assert len(cuts) == 2 and cuts[0] <= cuts[1] <= cfg.num_layers
        rep, _ = srv.step(_toks(cfg), 0, M.init_caches(cfg, 4, 32))
        assert rep.est_latency_s is not None and rep.est_latency_s > 0
        if not rep.exited.any():
            # No live exits -> the report's estimate is exactly the lattice
            # cost model at p == 0.
            zero_p = np.zeros(cfg.num_layers + 1)
            assert rep.est_latency_s == pytest.approx(expected_time_multitier(
                profile.t_c, profile.alpha, zero_p, tiers, srv.cuts
            ))
