"""Calibration + latency-model unit/property tests (Eq. 4 consistency,
Fig. 6 mechanism, distortion monotonicity)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    calibrate_exit_probs,
    normalized_entropy,
    threshold_sweep,
)
from repro.data.pipeline import DISTORTIONS, distort_embeddings, make_batch
from repro.configs import get_smoke_config


class TestEntropy:
    def test_uniform_is_one(self):
        h = normalized_entropy(jnp.zeros((3, 1000)))
        np.testing.assert_allclose(np.asarray(h), 1.0, atol=1e-6)

    def test_delta_is_zero(self):
        logits = jnp.full((2, 100), -40.0).at[:, 3].set(40.0)
        h = normalized_entropy(logits)
        np.testing.assert_allclose(np.asarray(h), 0.0, atol=1e-6)

    def test_invariant_to_shift(self):
        key = jax.random.PRNGKey(0)
        logits = jax.random.normal(key, (4, 64))
        h1 = normalized_entropy(logits)
        h2 = normalized_entropy(logits + 123.0)
        np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-5)


class TestCalibration:
    def test_eq4_consistency_sequential(self):
        rng = np.random.default_rng(0)
        ents = rng.uniform(0, 1, (3, 500))
        res = calibrate_exit_probs(ents, threshold=0.5)
        # unconditional p_Y(k) = p_k prod_{i<k} (1 - p_i)  (asserted inside,
        # re-checked here explicitly)
        alive = 1.0
        for k in range(3):
            assert res.unconditional_p[k] == pytest.approx(
                res.conditional_p[k] * alive
            )
            alive *= 1 - res.conditional_p[k]
        # exit fractions + tail sum to 1
        assert res.exit_fraction.sum() == pytest.approx(1.0)

    @settings(max_examples=50, deadline=None)
    @given(
        k=st.integers(1, 4),
        b=st.integers(1, 64),
        thr=st.floats(0.05, 0.95),
        seed=st.integers(0, 2**16),
    )
    def test_property_fractions_sum_to_one(self, k, b, thr, seed):
        rng = np.random.default_rng(seed)
        ents = rng.uniform(0, 1, (k, b))
        res = calibrate_exit_probs(ents, thr)
        assert res.exit_fraction.sum() == pytest.approx(1.0)
        assert ((0 <= res.conditional_p) & (res.conditional_p <= 1)).all()

    def test_threshold_sweep_monotone(self):
        rng = np.random.default_rng(1)
        ents = rng.uniform(0, 1, (2, 400))
        sweep = threshold_sweep(ents, np.linspace(0.1, 0.9, 9))
        # Higher threshold -> weakly more exits at the FIRST branch.
        assert np.all(np.diff(sweep[:, 0]) >= -1e-12)


class TestDistortion:
    def test_noise_raises_branch_entropy(self):
        """The Fig. 6 mechanism on the LM embedding stub: more distortion
        -> higher branch entropy (flatter posterior)."""
        from repro.models import model as M

        cfg = get_smoke_config("internvl2_76b")
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        batch = make_batch(cfg, 4, 24)
        key = jax.random.PRNGKey(5)

        def branch_entropy(noise):
            emb = distort_embeddings(key, jnp.asarray(batch["patch_embeds"]), noise)
            inputs = {"tokens": jnp.asarray(batch["tokens"]), "patch_embeds": emb}
            out = M.forward_train(params, {**inputs, "labels": jnp.asarray(batch["labels"])}, cfg)
            return out  # losses only; we want entropies - use decode path

        # Use prefill logits entropy as the confidence proxy.
        ents = {}
        for name, level in DISTORTIONS.items():
            emb = distort_embeddings(key, jnp.asarray(batch["patch_embeds"]), level)
            caches = M.init_caches(cfg, 4, 64)
            logits, _ = M.prefill(
                params,
                {"tokens": jnp.asarray(batch["tokens"]), "patch_embeds": emb},
                cfg, caches,
            )
            ents[name] = float(np.mean(np.asarray(normalized_entropy(logits[:, 0]))))
        # Entropies should not DECREASE as noise grows (untrained nets are
        # noisy; demand the low <= high ordering with tolerance).
        assert ents["low"] <= ents["high"] + 0.05
