"""Fault-injected hops and exit-head degradation (serving/faults.py and
the executor's fault plane):

  * seeded `LinkFaultModel` determinism: identical draws across runs,
    prefix-stable per-attempt streams, scripted flap windows, per-hop
    knob mappings;
  * `HopPolicy` backoff ordering (exponential + jitter) and the pinned
    per-attempt event trace of `attempt_hop`;
  * `CircuitBreaker` transitions: closed -> open at the failure
    threshold, skip during cooldown, half-open probe, close on probe
    success / re-open on probe failure;
  * degraded steps: a benign fault model is bitwise invisible; a link
    kill finalizes survivors from the deepest exit head at or below the
    broken hop (the at-cut head the healthy plan discards included),
    with one host sync and one cache-clock bump per step; forced exits
    never pollute `branch_take`; a hop with no head below it fails the
    step without touching the caches;
  * the `transfer_seconds` dead-uplink regression: a wall-clock hop
    with bytes queued and no uplink raises `LinkDownError` instead of
    sleeping zero seconds (satellite: silent-free dead links);
  * `RequestScheduler` retirement under faults: terminal `failed` /
    `degraded` statuses, requeue-on-fail, and the KV-slot allocator
    invariant (no leaked slots across fault churn);
  * `RepartitionController` hop health: EWMA purity (breaker skips are
    not observations; a failed half-open probe never touches the
    transfer-time estimate), solver avoidance of availability-0 hops,
    drift-window reset on fault-driven re-solves, and the end-to-end
    breaker-open -> re-solve -> cut-moves-off-the-sick-link loop.
"""

import dataclasses
import types

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import LayerCost, build_cost_profile
from repro.core.multitier import TierSpec, _hop_seconds, solve_multitier
from repro.models import model as M
from repro.serving import (
    MultiTierServer,
    RepartitionController,
    RequestScheduler,
    TierExecutor,
    segments_for_cuts,
)
from repro.serving.faults import (
    HEALTHY,
    CircuitBreaker,
    FaultEvent,
    FlapWindow,
    HopCondition,
    HopPolicy,
    LinkDownError,
    LinkFaultModel,
    attempt_hop,
)

B = 8


@pytest.fixture(scope="module")
def deep_model():
    """4 trunk layers, branches after v_1 and v_3, threshold calibrated to
    a mixed exit regime (as in test_scheduler)."""
    cfg = dataclasses.replace(
        get_smoke_config("phi3_mini_3_8b"), num_layers=4, branch_layers=(1, 3)
    )
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    ex = TierExecutor(cfg, params, segments_for_cuts(cfg, ()))
    tok = jax.random.randint(jax.random.PRNGKey(2), (B, 1), 0, cfg.vocab_size)
    res, _ = ex.step(tok, 0, M.init_caches(cfg, B, 32))
    ents = np.concatenate([res.branch_entropy[l] for l in cfg.branch_layers])
    cfg = dataclasses.replace(
        cfg, exit_threshold=float((ents.min() + ents.max()) / 2)
    )
    return cfg, params


def _tok0(cfg):
    return jax.random.randint(
        jax.random.PRNGKey(2), (B, 1), 0, cfg.vocab_size
    )


def _decode(cfg, params, cuts, *, fm=None, hp=None, steps=5, **kw):
    """Drive `steps` lock-step decode steps; return (executor, history)."""
    ex = TierExecutor(
        cfg, params,
        segments_for_cuts(cfg, cuts, uplinks=(1e9,) * len(cuts)),
        simulate_network=True, fault_model=fm, hop_policy=hp, **kw,
    )
    caches = M.init_caches(cfg, B, 32)
    tok = _tok0(cfg)
    hist = []
    for i in range(steps):
        res, caches = ex.step(tok, i, caches)
        hist.append(res)
        tok = res.tokens_dev[:, None]
    return ex, hist


KILL_HOP1 = LinkFaultModel(
    seed=0, flaps=(FlapWindow(hop=1, start_step=2, end_step=10_000),)
)
FAST_POLICY = HopPolicy(
    timeout_s=0.01, max_retries=1, backoff_s=0.001,
    breaker_threshold=2, breaker_cooldown_steps=3,
)


class TestLinkFaultModel:
    def test_draw_deterministic_and_prefix_stable(self):
        m = LinkFaultModel(seed=3, drop_p=0.5, spike_p=0.3, spike_s=0.01)
        c1, j1, d1 = m.draw(2, 0, 3)
        c2, j2, d2 = m.draw(2, 0, 3)
        assert c1 == c2 and j1 == j2 and np.array_equal(d1, d2)
        # PCG64 stream is prefix-stable: a policy allowing more attempts
        # sees the same leading drop flags, so retry budgets never shift
        # the fault schedule.
        _, _, d5 = m.draw(2, 0, 5)
        assert np.array_equal(d1, d5[:3])
        # Different (step, hop) keys draw independent streams.
        assert not all(
            np.array_equal(m.draw(s, h, 8)[2], m.draw(2, 0, 8)[2])
            for s, h in [(3, 0), (2, 1)]
        )

    def test_flap_windows(self):
        m = LinkFaultModel(
            seed=0, flaps=(FlapWindow(hop=1, start_step=5, end_step=8),)
        )
        assert m.flapped(5, 1) and m.flapped(7, 1)
        assert not m.flapped(8, 1)  # end exclusive
        assert not m.flapped(6, 0)  # other hops untouched
        assert m.condition(6, 1).flapped
        assert not m.condition(4, 1).flapped

    def test_per_hop_mapping_knobs(self):
        m = LinkFaultModel(seed=0, drop_p={0: 1.0}, bandwidth_mult={1: 0.5})
        _, _, d0 = m.draw(0, 0, 4)
        _, _, d1 = m.draw(0, 1, 4)
        assert d0.all() and not d1.any()  # unlisted hop gets the default
        assert m.condition(0, 0).bandwidth_mult == 1.0
        assert m.condition(0, 1).bandwidth_mult == 0.5


class TestHopPolicy:
    def test_backoff_exponential_with_jitter(self):
        p = HopPolicy(backoff_s=0.01, backoff_mult=2.0, jitter_frac=0.5)
        assert p.backoff(1) == pytest.approx(0.01)
        assert p.backoff(2) == pytest.approx(0.02)
        assert p.backoff(3) == pytest.approx(0.04)
        assert p.backoff(1, jitter_u=1.0) == pytest.approx(0.015)

    def test_attempt_hop_event_ordering_when_down(self):
        """Pinned trace for a hard-down hop with one retry:
        link_down(0), retry(1), link_down(1), exhausted — and the
        overhead is two timeouts plus the first backoff."""
        p = HopPolicy(timeout_s=0.01, max_retries=1, backoff_s=0.002)
        out = attempt_hop(
            p, HopCondition(flapped=True), [False, False], 0.0,
            step=4, hop=1, est_bytes=100.0, uplink_bps=1e9, attempts=2,
        )
        assert not out.ok and out.attempts == 2
        assert [e.kind for e in out.events] == [
            "link_down", "retry", "link_down", "exhausted",
        ]
        assert [e.attempt for e in out.events[:3]] == [0, 1, 1]
        assert all(e.step == 4 and e.hop == 1 for e in out.events)
        assert out.overhead_s == pytest.approx(2 * 0.01 + 0.002)

    def test_attempt_hop_drop_then_success(self):
        p = HopPolicy(timeout_s=0.05, max_retries=2, backoff_s=0.001)
        out = attempt_hop(
            p, HEALTHY, [True, False, False], 0.0,
            step=0, hop=0, est_bytes=1000.0, uplink_bps=1e9, attempts=3,
        )
        assert out.ok and out.attempts == 2
        assert [e.kind for e in out.events] == ["drop", "retry"]
        assert out.overhead_s == pytest.approx(0.05 + 0.001)

    def test_attempt_hop_timeout_admission(self):
        """The estimated transfer of the worst-case payload exceeding the
        deadline fails the attempt without any device work."""
        p = HopPolicy(timeout_s=0.001, max_retries=0)
        out = attempt_hop(
            p, HEALTHY, [False], 0.0,
            step=0, hop=0, est_bytes=10e6, uplink_bps=1e6, attempts=1,
        )
        assert not out.ok
        assert [e.kind for e in out.events] == ["timeout", "exhausted"]


class TestCircuitBreaker:
    def test_transitions(self):
        b = CircuitBreaker(HopPolicy(breaker_threshold=3,
                                     breaker_cooldown_steps=4))
        assert b.gate(0) == "attempt"
        for s in range(3):
            b.record(s, ok=False)
        assert b.state == "open"
        assert b.gate(3) == "skip"  # cooling down
        assert b.gate(2 + 4) == "probe"  # cooldown elapsed -> half-open
        assert b.state == "half_open"
        b.record(6, ok=True)
        assert b.state == "closed" and b.failures == 0

    def test_half_open_failure_reopens(self):
        b = CircuitBreaker(HopPolicy(breaker_threshold=2,
                                     breaker_cooldown_steps=2))
        b.record(0, ok=False)
        b.record(1, ok=False)
        assert b.gate(1 + 2) == "probe"
        b.record(3, ok=False)  # one probe failure re-opens immediately
        assert b.state == "open"
        assert b.gate(4) == "skip"  # cooldown restarted from the re-open
        assert b.gate(3 + 2) == "probe"


class TestDegradedSteps:
    @pytest.mark.parametrize("cuts", [(2,), (1, 3)])
    @pytest.mark.parametrize("compaction", ["bucketed", "off"])
    def test_benign_model_is_bitwise_invisible(self, deep_model, cuts,
                                               compaction):
        """An armed fault plane with a benign model (no drops, mult 1,
        no spikes) must not perturb the trajectory by one bit."""
        cfg, params = deep_model
        _, base = _decode(cfg, params, cuts, compaction=compaction)
        _, ben = _decode(cfg, params, cuts, fm=LinkFaultModel(seed=0),
                         compaction=compaction)
        for a, b in zip(base, ben):
            assert np.array_equal(a.tokens, b.tokens)
            assert np.array_equal(a.exit_tier, b.exit_tier)
            assert b.degraded is None or not b.degraded.any()
            assert b.degraded_hop is None

    def test_benign_model_is_bitwise_invisible_with_kernels(self, deep_model):
        cfg, params = deep_model
        _, base = _decode(cfg, params, (1, 3), steps=3, use_kernels=True)
        _, ben = _decode(cfg, params, (1, 3), steps=3, use_kernels=True,
                         fm=LinkFaultModel(seed=0))
        for a, b in zip(base, ben):
            assert np.array_equal(a.tokens, b.tokens)

    def test_link_kill_degrades_via_fallback_head(self, deep_model):
        """Mid-run hop-1 kill (cuts (1,3)): the broken hop's cut is layer
        3, so survivors are force-finalized from the branch-3 head on the
        mid tier — the head the healthy plan discards at the cut."""
        cfg, params = deep_model
        ex, base = _decode(cfg, params, (1, 3), steps=6)
        ex2, hist = _decode(cfg, params, (1, 3), fm=KILL_HOP1,
                            hp=FAST_POLICY, steps=6)
        # Healthy prefix identical; faulted steps all-exited.
        for a, b in zip(base[:2], hist[:2]):
            assert np.array_equal(a.tokens, b.tokens)
        assert ex2.degraded_steps > 0 and ex2.failed_steps == 0
        assert ex2.fault_retries > 0
        saw = False
        for s, res in enumerate(hist[2:], start=2):
            assert res.exited.all()  # every live row finalized
            if res.degraded is not None and res.degraded.any():
                saw = True
                assert res.degraded_hop == 1
                # Forced rows exit on the tier holding the fallback head
                # and are never reported as genuine branch exits.
                assert (res.exit_tier[res.degraded] == 1).all()
                for take in res.branch_take.values():
                    assert not (take & res.degraded).any()
                # Nothing shipped on or past the broken hop.
                assert res.shipped_per_hop[1] == 0
                assert res.bytes_per_hop[1] == 0.0
        assert saw
        # Breaker lifecycle in the trace: retries exhaust, the breaker
        # opens, then cooldown steps skip the hop without touching it.
        kinds = [e.kind for res in hist for e in res.fault_events]
        for k in ("link_down", "retry", "exhausted", "breaker_open",
                  "breaker_skip"):
            assert k in kinds, k

    def test_forced_tokens_are_fallback_head_argmax(self, deep_model):
        """Step-0 hop-0 kill (cuts (2,): branch 1 lives below the cut) vs
        a healthy run whose threshold makes every row genuinely exit at
        branch 1: identical tokens, because forced finalization takes the
        same branch-head argmax the threshold exit would have taken."""
        cfg, params = deep_model
        all_exit = dataclasses.replace(cfg, exit_threshold=float("inf"))
        _, ref = _decode(all_exit, params, (2,), steps=1)
        assert ref[0].exited.all()  # the reference exits genuinely
        fm = LinkFaultModel(
            seed=0, flaps=(FlapWindow(hop=0, start_step=0, end_step=10),)
        )
        _, forced = _decode(cfg, params, (2,), fm=fm, hp=FAST_POLICY,
                            steps=1)
        assert np.array_equal(ref[0].tokens, forced[0].tokens)
        assert forced[0].exited.all()
        # Degraded rows are exactly the complement of the genuine branch-1
        # exits — forced finalization and threshold exit share the head.
        assert forced[0].degraded is not None
        assert np.array_equal(forced[0].degraded,
                              ~forced[0].branch_take[1])

    def test_degraded_step_bumps_cache_clock_once(self, deep_model):
        """One KV ring-buffer advance per degraded step — the fallback
        segment variant owns the bump the absent head tier would have
        made."""
        cfg, params = deep_model
        ex = TierExecutor(
            cfg, params, segments_for_cuts(cfg, (1, 3), uplinks=(1e9, 1e9)),
            simulate_network=True, fault_model=KILL_HOP1,
            hop_policy=FAST_POLICY,
        )
        caches = M.init_caches(cfg, B, 32)
        tok = _tok0(cfg)
        for i in range(4):
            before = int(np.asarray(caches["length"]).max())
            res, caches = ex.step(tok, i, caches)
            after = int(np.asarray(caches["length"]).max())
            assert after == before + 1
            tok = res.tokens_dev[:, None]
        assert ex.degraded_steps > 0

    def test_one_sync_per_degraded_step(self, deep_model):
        cfg, params = deep_model
        ex, _ = _decode(cfg, params, (1, 3), fm=KILL_HOP1, hp=FAST_POLICY,
                        steps=6)
        assert ex.host_syncs == 6
        assert ex.degraded_steps > 0

    def test_no_head_below_hop_fails_step(self, deep_model):
        """Branch only at layer 3, cut after 2: a hop-0 kill leaves no
        exit head at or below the cut — the step fails every live row,
        emits nothing, and leaves the caches (clock included) untouched
        with zero device syncs."""
        cfg, params = deep_model
        cfg = dataclasses.replace(cfg, branch_layers=(3,), exit_threshold=0.0)
        fm = LinkFaultModel(
            seed=0, flaps=(FlapWindow(hop=0, start_step=0, end_step=10),)
        )
        ex = TierExecutor(
            cfg, params, segments_for_cuts(cfg, (2,), uplinks=(1e9,)),
            simulate_network=True, fault_model=fm, hop_policy=FAST_POLICY,
        )
        caches = M.init_caches(cfg, B, 32)
        before = np.asarray(caches["length"]).copy()
        res, caches = ex.step(_tok0(cfg), 0, caches)
        assert res.failed.all() and not res.degraded.any()
        assert (res.exit_tier == -1).all()
        assert np.array_equal(np.asarray(caches["length"]), before)
        assert ex.host_syncs == 0 and ex.failed_steps == 1

    def test_seeded_fault_runs_are_deterministic(self, deep_model):
        """Satellite: same model seed + schedule -> identical fault
        events, retry counts, degraded masks, and tokens across runs."""
        cfg, params = deep_model
        fm = LinkFaultModel(
            seed=7, drop_p=0.3, spike_p=0.2, spike_s=0.005,
            flaps=(FlapWindow(hop=1, start_step=3, end_step=5),),
        )
        ex1, h1 = _decode(cfg, params, (1, 3), fm=fm, hp=FAST_POLICY, steps=6)
        ex2, h2 = _decode(cfg, params, (1, 3), fm=fm, hp=FAST_POLICY, steps=6)
        assert ex1.fault_retries == ex2.fault_retries
        assert ex1.degraded_steps == ex2.degraded_steps
        for a, b in zip(h1, h2):
            assert a.fault_events == b.fault_events
            assert np.array_equal(a.tokens, b.tokens)
            assert (a.degraded is None) == (b.degraded is None)
            if a.degraded is not None:
                assert np.array_equal(a.degraded, b.degraded)


class TestDeadUplink:
    def test_unset_uplink_raises_instead_of_free_transfer(self, deep_model):
        """Satellite: simulate_network with bytes queued on a hop whose
        uplink_bps is unset/zero must raise, not price the hop at zero
        seconds."""
        cfg, params = deep_model
        cfg = dataclasses.replace(cfg, exit_threshold=0.0)  # nobody exits
        ex = TierExecutor(
            cfg, params, segments_for_cuts(cfg, (2,)),  # uplink defaults 0
            simulate_network=True,
        )
        with pytest.raises(LinkDownError, match="hop 0"):
            ex.step(_tok0(cfg), 0, M.init_caches(cfg, B, 32))

    def test_no_payload_no_raise(self, deep_model):
        """A dead uplink that never ships (every row exits below the cut)
        stays silent — the regression only triggers on queued bytes."""
        cfg, params = deep_model
        cfg = dataclasses.replace(cfg, exit_threshold=float("inf"))
        ex = TierExecutor(
            cfg, params, segments_for_cuts(cfg, (2,)), simulate_network=True,
        )
        res, _ = ex.step(_tok0(cfg), 0, M.init_caches(cfg, B, 32))
        assert res.exited.all() and res.bytes_per_hop[0] == 0.0

    def test_fault_model_degrades_instead_of_raising(self, deep_model):
        """With a LinkFaultModel attached the same dead uplink becomes a
        planned link-down: retries burn out and the step degrades."""
        cfg, params = deep_model
        cfg = dataclasses.replace(cfg, exit_threshold=0.0)
        ex = TierExecutor(
            cfg, params, segments_for_cuts(cfg, (2,)),
            simulate_network=True, fault_model=LinkFaultModel(seed=0),
            hop_policy=FAST_POLICY,
        )
        res, _ = ex.step(_tok0(cfg), 0, M.init_caches(cfg, B, 32))
        assert res.degraded_hop == 0
        assert res.exited.all()


def _profile(cfg):
    costs = [
        LayerCost(f"l{i}", 0, 0, cfg.d_model * 2.0, 1e-3)
        for i in range(cfg.num_layers)
    ]
    return build_cost_profile(
        costs, cfg.branch_layers, np.array([0.2, 0.2]), "3g", 50.0, 64.0
    )


def _prompts(cfg, n, plen, seed=5):
    r = np.random.default_rng(seed)
    return [
        r.integers(0, cfg.vocab_size, size=plen).astype(np.int32)
        for _ in range(n)
    ]


def _fault_server(cfg, params, fm, hp, *, tiers=None, cuts=(1, 3), slots=4):
    tiers = tiers or [
        TierSpec("edge", 4.0, 1e9),
        TierSpec("mid", 2.0, 1e9),
        TierSpec("cloud", 1.0),
    ]
    return MultiTierServer(
        cfg, params, tiers, cuts, simulate_network=True,
        slots=slots, context_len=64, fault_model=fm, hop_policy=hp,
    )


class TestSchedulerFaults:
    def test_drain_completes_under_link_kill(self, deep_model):
        """Every in-flight and queued request finishes despite a mid-run
        hop kill; no slot leaks; degraded tokens are attributed."""
        cfg, params = deep_model
        fm = LinkFaultModel(
            seed=0, flaps=(FlapWindow(hop=1, start_step=4, end_step=10_000),)
        )
        srv = _fault_server(cfg, params, fm, FAST_POLICY)
        sched = RequestScheduler(srv, 4, 64)
        for p in _prompts(cfg, 8, 6):
            sched.submit(p, 8)
        results = sched.drain()
        assert len(results) == 8 and all(r.done for r in results)
        assert {r.status for r in results} <= {"ok", "degraded"}
        assert sum(r.degraded_tokens for r in results) > 0
        assert sched.active.sum() == 0
        assert all(r is None for r in sched._slot_req)

    def test_terminal_failed_reclaims_slots(self, deep_model):
        """No fallback head below the broken hop and requeue disabled:
        requests retire with status 'failed', their slots are reclaimed,
        and queued requests still cycle through (and fail) — the drain
        terminates with the allocator empty."""
        cfg, params = deep_model
        cfg = dataclasses.replace(cfg, branch_layers=(3,), exit_threshold=0.0)
        fm = LinkFaultModel(
            seed=0, flaps=(FlapWindow(hop=0, start_step=0, end_step=10_000),)
        )
        tiers = [TierSpec("edge", 4.0, 1e9), TierSpec("cloud", 1.0)]
        srv = _fault_server(cfg, params, fm, FAST_POLICY,
                            tiers=tiers, cuts=(2,), slots=2)
        sched = RequestScheduler(srv, 2, 64)
        for p in _prompts(cfg, 4, 6):
            sched.submit(p, 4)
        results = sched.drain()
        assert len(results) == 4
        assert all(r.done and r.status == "failed" for r in results)
        assert all(r.tokens == [] for r in results)
        assert sched.active.sum() == 0
        assert all(r is None for r in sched._slot_req)

    def test_requeue_on_fail_recovers_after_flap(self, deep_model):
        """A finite flap with requeue_on_fail: failed requests re-enter
        the queue, re-admit after the link recovers, and complete
        cleanly from a fresh admission."""
        cfg, params = deep_model
        cfg = dataclasses.replace(cfg, branch_layers=(3,), exit_threshold=0.0)
        fm = LinkFaultModel(
            seed=0, flaps=(FlapWindow(hop=0, start_step=2, end_step=5),)
        )
        hp = HopPolicy(timeout_s=0.01, max_retries=0,
                       breaker_threshold=100)  # no breaker: probe the flap
        tiers = [TierSpec("edge", 4.0, 1e9), TierSpec("cloud", 1.0)]
        srv = _fault_server(cfg, params, fm, hp,
                            tiers=tiers, cuts=(2,), slots=2)
        sched = RequestScheduler(srv, 2, 64, requeue_on_fail=True,
                                 max_requeues=8)
        for p in _prompts(cfg, 2, 6):
            sched.submit(p, 4)
        saw_fail_step = False
        for _ in range(200):
            rep = sched.step()
            if rep is not None and rep.failed:
                saw_fail_step = True
            if not sched.queue and not sched.active.any():
                break
        results = [sched.results[r] for r in sorted(sched.results)]
        assert saw_fail_step
        assert all(r.done and r.status == "ok" for r in results)
        assert all(len(r.tokens) == 4 for r in results)
        assert sched.active.sum() == 0
        assert all(r is None for r in sched._slot_req)


class TestControllerHopHealth:
    def test_hop_seconds_availability_math(self):
        assert _hop_seconds(8e9, 1e9) == pytest.approx(8.0)
        assert _hop_seconds(8e9, 1e9, availability=0.5) == pytest.approx(16.0)
        assert _hop_seconds(8e9, 1e9, availability=0.0) == float("inf")
        assert _hop_seconds(0.0, 1e9, availability=0.0) == 0.0

    def test_solver_avoids_dead_hop(self):
        """availability=0 on a hop prices any payload across it at +inf;
        the optimal plan ships zero bytes on it (cut at L)."""
        L = 6
        t_c = np.concatenate([[0.0], np.full(L, 1e-3)])
        alpha = np.concatenate([[64.0], np.full(L, 64.0)])
        p = np.zeros(L + 1)
        p[2] = 0.6
        tiers = [
            TierSpec("edge", 2.0, 1e8),
            TierSpec("mid", 1.5, 1e8, availability=0.0),
            TierSpec("cloud", 1.0),
        ]
        plan = solve_multitier(t_c, alpha, p, tiers)
        assert plan.cut_after[1] == L  # nothing may cross the dead hop
        healthy = [dataclasses.replace(t, availability=1.0) for t in tiers]
        ref = solve_multitier(t_c, alpha, p, healthy)
        assert ref.cut_after[1] < L  # ...which the healthy plan uses

    def _controller(self, deep_model, **kw):
        cfg, params = deep_model
        tiers = [
            TierSpec("edge", 4.0, 1e9),
            TierSpec("mid", 2.0, 1e9),
            TierSpec("cloud", 1.0),
        ]
        srv = MultiTierServer(cfg, params, tiers, (1, 3), slots=4,
                              context_len=64)
        return RepartitionController(srv, _profile(cfg), tiers=tiers, **kw), srv

    @staticmethod
    def _report(events=(), broken=None, nb=(100.0, 100.0),
                sim=(1e-4, 1e-4)):
        return types.SimpleNamespace(
            fault_events=tuple(events), degraded_hop=broken,
            bytes_per_hop=tuple(nb), sim_transfer_s=tuple(sim),
        )

    def test_breaker_skip_is_not_an_observation(self, deep_model):
        ctl, _ = self._controller(deep_model, fault_resolve=False)
        ctl._ingest_faults(self._report(
            events=[FaultEvent(0, 0, "breaker_skip")], broken=0,
        ))
        assert 0 not in ctl._hop_avail and 0 not in ctl._hop_xfer

    def test_probe_failure_never_touches_xfer_ewma(self, deep_model):
        """Satellite: a failed half-open probe moves availability but the
        transfer-time EWMA only ever ingests successful shipments."""
        ctl, _ = self._controller(deep_model, fault_resolve=False)
        ctl._hop_xfer[0] = 5.0
        ctl._ingest_faults(self._report(
            events=[FaultEvent(3, 0, "breaker_half_open"),
                    FaultEvent(3, 0, "link_down", 0),
                    FaultEvent(3, 0, "exhausted", 0)],
            broken=0, nb=(0.0, 0.0), sim=(0.0, 0.0),
        ))
        assert ctl._hop_xfer[0] == 5.0
        assert ctl._hop_avail[0] == pytest.approx(1.0 - ctl.hop_alpha)

    def test_successful_hops_feed_both_ewmas(self, deep_model):
        ctl, _ = self._controller(deep_model, fault_resolve=False)
        ctl._ingest_faults(self._report(
            events=[FaultEvent(0, 0, "drop", 0)],  # any event arms ingest
            nb=(1000.0, 1000.0), sim=(2e-3, 4e-3),
        ))
        assert ctl._hop_avail[0] == 1.0 and ctl._hop_avail[1] == 1.0
        assert ctl._hop_xfer[0] == pytest.approx(2e-3)
        assert ctl._hop_xfer[1] == pytest.approx(4e-3)
        health = ctl.hop_health()
        assert not health[0]["open"]

    def test_breaker_open_resolves_and_resets_drift_window(self, deep_model):
        """Satellite: a breaker_open event re-solves through update_tiers
        — availability 0 lands in the server's specs and the drift window
        restarts under the new plan."""
        ctl, srv = self._controller(deep_model)
        ctl._installed_p = np.array([0.2, 0.2])
        ctl._arrivals[:] = [8.0, 4.0]
        ctl._exits[:] = [2.0, 1.0]
        ctl._window_age = 7
        cuts = ctl._ingest_faults(self._report(
            events=[FaultEvent(2, 1, "exhausted", 1),
                    FaultEvent(2, 1, "breaker_open")],
            broken=1,
        ))
        assert cuts is not None and ctl.fault_resolves == 1
        assert srv.tiers[1].availability == 0.0
        assert srv.cuts[1] == cfg_layers(srv)  # nothing crosses the hop
        assert ctl._arrivals.sum() == 0 and ctl._exits.sum() == 0
        assert ctl._window_age == 0
        assert ctl.hop_health()[1]["open"]

    def test_breaker_closed_forgives_and_can_stay_manual(self, deep_model):
        """Recovery with fault_resolve=False: ingestion tracks the closed
        breaker (open set cleared, availability forgiven to 1.0) but
        never re-solves on its own."""
        ctl, srv = self._controller(deep_model, fault_resolve=False)
        before = srv.cuts
        ctl._ingest_faults(self._report(
            events=[FaultEvent(2, 1, "exhausted", 1),
                    FaultEvent(2, 1, "breaker_open")],
            broken=1,
        ))
        assert ctl.hop_health()[1]["open"] and ctl.fault_resolves == 0
        ctl._ingest_faults(self._report(
            events=[FaultEvent(6, 1, "breaker_half_open"),
                    FaultEvent(6, 1, "breaker_closed")],
        ))
        assert not ctl._hop_open
        assert ctl._hop_avail[1] == 1.0
        assert ctl.fault_resolves == 0 and srv.cuts == before

    def test_e2e_breaker_open_moves_cut_off_sick_link(self, deep_model):
        """The loop the tentpole promises: link kill -> retries exhaust ->
        breaker opens -> controller re-solves -> the new cuts ship zero
        bytes on the sick hop -> requests keep completing."""
        cfg, params = deep_model
        fm = LinkFaultModel(
            seed=0, flaps=(FlapWindow(hop=1, start_step=4, end_step=10_000),)
        )
        srv = _fault_server(cfg, params, fm, FAST_POLICY)
        ctl = RepartitionController(srv, _profile(cfg),
                                    tiers=list(srv.tiers))
        sched = RequestScheduler(srv, 4, 64, on_step=[ctl.observe])
        for p in _prompts(cfg, 8, 6):
            sched.submit(p, 10)
        results = sched.drain()
        assert all(r.done for r in results)
        assert ctl.fault_resolves >= 1
        assert srv.tiers[1].availability == 0.0
        assert srv.cuts[1] == cfg.num_layers  # hop 1 carries nothing now
        assert sched.active.sum() == 0
        assert all(r is None for r in sched._slot_req)


def cfg_layers(srv) -> int:
    return srv.cfg.num_layers
