"""Trip-count-corrected HLO analysis (launch/hlo_analysis.py): validated
against unrolled references — this is what makes the roofline table honest
(XLA cost_analysis counts while-loop bodies once)."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze_hlo


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


class TestDotFlops:
    def test_scan_matches_unrolled(self):
        w = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
        x = jax.ShapeDtypeStruct((4, 256), jnp.float32)

        def scanned(w, x):
            def body(c, wi):
                return jnp.tanh(c @ wi), None

            out, _ = jax.lax.scan(body, x, w)
            return out

        def unrolled(w, x):
            for i in range(8):
                x = jnp.tanh(x @ w[i])
            return x

        fs = analyze_hlo(_compile(scanned, w, x))["dot_flops"]
        fu = analyze_hlo(_compile(unrolled, w, x))["dot_flops"]
        expected = 2 * 8 * 4 * 256 * 256
        assert fs == pytest.approx(expected, rel=0.01)
        assert fu == pytest.approx(expected, rel=0.01)

    def test_nested_scans_multiply(self):
        w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
        x = jax.ShapeDtypeStruct((4, 128), jnp.float32)

        def nested(w, x):
            def outer(c, _):
                def body(cc, wi):
                    return jnp.tanh(cc @ wi), None

                c2, _ = jax.lax.scan(body, c, w)
                return c2, None

            out, _ = jax.lax.scan(outer, x, None, length=5)
            return out

        f = analyze_hlo(_compile(nested, w, x))["dot_flops"]
        assert f == pytest.approx(5 * 8 * 2 * 4 * 128 * 128, rel=0.01)

    def test_bytes_scale_with_trip_count(self):
        w = jax.ShapeDtypeStruct((16, 128, 128), jnp.float32)
        x = jax.ShapeDtypeStruct((4, 128), jnp.float32)

        def scanned(w, x):
            def body(c, wi):
                return jnp.tanh(c @ wi), None

            out, _ = jax.lax.scan(body, x, w)
            return out

        b = analyze_hlo(_compile(scanned, w, x))["hbm_bytes"]
        # Dominated by streaming the 16 weight slices: >= 16 * 64 KB.
        assert b >= 16 * 128 * 128 * 4

    def test_no_loops_ok(self):
        x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
        r = analyze_hlo(_compile(lambda a: a @ a, x))
        assert r["dot_flops"] == pytest.approx(2 * 32**3, rel=0.01)
        assert all(v == 0 for v in r["collectives"].values())
