"""Pallas kernel correctness: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True executes kernel bodies on CPU).  Deliverable (c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.entropy_exit import entropy_exit_pallas
from repro.kernels.flash_decode import flash_decode_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-4, atol=2e-4
    )


class TestEntropyExit:
    @pytest.mark.parametrize("b,v", [(1, 128), (4, 1000), (8, 2048), (3, 5003),
                                     (16, 32064), (2, 151936)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, b, v, dtype):
        key = jax.random.PRNGKey(b * v)
        logits = (jax.random.normal(key, (b, v), jnp.float32) * 4).astype(dtype)
        h, ex = entropy_exit_pallas(logits, 0.6, interpret=True)
        hr, exr = ref.entropy_exit_ref(logits, 0.6)
        np.testing.assert_allclose(np.asarray(h), np.asarray(hr), **tol(dtype))
        # Flags may differ only for entropies within tolerance of the knife edge.
        diff = np.asarray(ex) != np.asarray(exr)
        assert np.all(np.abs(np.asarray(hr)[diff] - 0.6) < 1e-2)

    def test_threshold_semantics(self):
        # A delta distribution has ~zero entropy -> always exits.
        logits = jnp.full((2, 512), -30.0).at[:, 7].set(30.0)
        h, ex = entropy_exit_pallas(logits, 0.1, interpret=True)
        assert np.asarray(ex).all()
        # Uniform -> entropy 1 -> never exits.
        h, ex = entropy_exit_pallas(jnp.zeros((2, 512)), 0.99, interpret=True)
        assert np.allclose(np.asarray(h), 1.0, atol=1e-5)
        assert not np.asarray(ex).any()


class TestFlashDecode:
    @pytest.mark.parametrize(
        "b,h,kh,d,c,window,length",
        [
            (2, 8, 2, 128, 1024, 0, 700),
            (1, 4, 4, 64, 513, 0, 513),
            (3, 16, 4, 128, 2048, 256, 2048),
            (2, 8, 1, 128, 100, 0, 37),
            (1, 32, 8, 128, 4096, 1024, 4096),
        ],
    )
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, b, h, kh, d, c, window, length, dtype):
        ks = jax.random.split(jax.random.PRNGKey(length), 3)
        q = jax.random.normal(ks[0], (b, h, d), jnp.float32).astype(dtype)
        k = jax.random.normal(ks[1], (b, c, kh, d), jnp.float32).astype(dtype)
        v = jax.random.normal(ks[2], (b, c, kh, d), jnp.float32).astype(dtype)
        pos = np.full(c, -1, np.int32)
        pos[:length] = np.arange(length)
        pos = jnp.asarray(pos)
        qpos = jnp.asarray(length, jnp.int32)
        o = flash_decode_pallas(q, k, v, pos, qpos, window=window, interpret=True)
        r = ref.flash_decode_ref(q, k, v, pos, qpos, window=window)
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(r, np.float32), **tol(dtype))

    def test_per_sequence_slot_validity(self):
        """(B, C) k_pos: each sequence masks its own holes (the compacted
        runtime leaves -1 slots in rows that skipped a step downstream)."""
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        b, h, kh, d, c, length = 3, 8, 2, 64, 512, 300
        q = jax.random.normal(ks[0], (b, h, d))
        k = jax.random.normal(ks[1], (b, c, kh, d))
        v = jax.random.normal(ks[2], (b, c, kh, d))
        pos = np.full((b, c), -1, np.int32)
        rng = np.random.default_rng(7)
        for r in range(b):
            pos[r, :length] = np.arange(length)
            pos[r, rng.choice(length, size=40, replace=False)] = -1  # holes
        pos = jnp.asarray(pos)
        qpos = jnp.asarray(length, jnp.int32)
        o = flash_decode_pallas(q, k, v, pos, qpos, interpret=True)
        r = ref.flash_decode_ref(q, k, v, pos, qpos)
        np.testing.assert_allclose(np.asarray(o), np.asarray(r), rtol=2e-4,
                                   atol=2e-4)

    def test_survivor_row_map(self):
        """rows scalar-prefetch: a compacted sub-batch attends in place
        against survivor rows of a larger resident cache."""
        ks = jax.random.split(jax.random.PRNGKey(4), 3)
        bc, b, h, kh, d, c, length = 6, 2, 8, 2, 64, 256, 200
        q = jax.random.normal(ks[0], (b, h, d))
        k = jax.random.normal(ks[1], (bc, c, kh, d))
        v = jax.random.normal(ks[2], (bc, c, kh, d))
        pos = np.full((bc, c), -1, np.int32)
        pos[:, :length] = np.arange(length)
        pos = jnp.asarray(pos)
        qpos = jnp.asarray(length, jnp.int32)
        rows = jnp.asarray([5, 2], jnp.int32)
        o = flash_decode_pallas(q, k, v, pos, qpos, rows, interpret=True)
        r = ref.flash_decode_ref(q, k, v, pos, qpos, rows)
        np.testing.assert_allclose(np.asarray(o), np.asarray(r), rtol=2e-4,
                                   atol=2e-4)
        # Identical to gathering the cache rows up front.
        o2 = flash_decode_pallas(
            q, k[rows], v[rows], pos[rows], qpos, interpret=True
        )
        np.testing.assert_allclose(np.asarray(o), np.asarray(o2), rtol=1e-5,
                                   atol=1e-5)

    def test_ring_cache_order_irrelevant(self):
        """Attention must depend on stored positions, not slot order."""
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        b, h, kh, d, c = 1, 4, 2, 64, 64
        q = jax.random.normal(ks[0], (b, h, d))
        k = jax.random.normal(ks[1], (b, c, kh, d))
        v = jax.random.normal(ks[2], (b, c, kh, d))
        pos = jnp.arange(c)
        o1 = flash_decode_pallas(q, k, v, pos, jnp.asarray(c), interpret=True)
        perm = np.random.default_rng(0).permutation(c)
        o2 = flash_decode_pallas(
            q, k[:, perm], v[:, perm], pos[perm], jnp.asarray(c), interpret=True
        )
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5, atol=1e-5)


class TestSSDScan:
    @pytest.mark.parametrize(
        "b,l,h,p,n,chunk",
        [
            (2, 64, 4, 64, 32, 16),
            (1, 100, 2, 128, 64, 32),
            (2, 256, 3, 64, 128, 128),
            (1, 128, 24, 64, 128, 64),  # mamba2-130m block shape
        ],
    )
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_sequential_ref(self, b, l, h, p, n, chunk, dtype):
        ks = jax.random.split(jax.random.PRNGKey(l * h), 4)
        x = (jax.random.normal(ks[0], (b, l, h, p)) * 0.5).astype(dtype)
        a = -jnp.abs(jax.random.normal(ks[1], (b, l, h))) * 0.3
        bm = (jax.random.normal(ks[2], (b, l, h, n)) * 0.5).astype(dtype)
        cm = (jax.random.normal(ks[3], (b, l, h, n)) * 0.5).astype(dtype)
        y, hf = ssd_scan_pallas(x, a.astype(dtype), bm, cm, chunk=chunk,
                                interpret=True)
        yr, hr = ref.ssd_scan_ref(x, a.astype(dtype), bm, cm)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(yr, np.float32), **tol(dtype))
        np.testing.assert_allclose(np.asarray(hf), np.asarray(hr),
                                   **tol(dtype))

    def test_matches_model_ssd(self):
        """The kernel agrees with the model's jnp chunked implementation."""
        from repro.models.mamba import ssd_chunked

        ks = jax.random.split(jax.random.PRNGKey(5), 4)
        b, l, h, p, n = 2, 96, 4, 64, 32
        x = jax.random.normal(ks[0], (b, l, h, p)) * 0.5
        a = -jnp.abs(jax.random.normal(ks[1], (b, l, h))) * 0.3
        bm = jax.random.normal(ks[2], (b, l, h, n)) * 0.5
        cm = jax.random.normal(ks[3], (b, l, h, n)) * 0.5
        y_k, h_k = ssd_scan_pallas(x, a, bm, cm, chunk=32, interpret=True)
        y_m, h_m = ssd_chunked(x, a, bm, cm, chunk=32)
        np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_m),
                                   rtol=1e-4, atol=1e-4)
