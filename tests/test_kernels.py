"""Pallas kernel correctness: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True executes kernel bodies on CPU).  Deliverable (c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.entropy_exit import (
    entropy_exit_argmax_pallas,
    entropy_exit_pallas,
)
from repro.kernels.flash_decode import flash_decode_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas, ssd_update_pallas


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-4, atol=2e-4
    )


class TestEntropyExit:
    @pytest.mark.parametrize("b,v", [(1, 128), (4, 1000), (8, 2048), (3, 5003),
                                     (16, 32064), (2, 151936)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, b, v, dtype):
        key = jax.random.PRNGKey(b * v)
        logits = (jax.random.normal(key, (b, v), jnp.float32) * 4).astype(dtype)
        h, ex = entropy_exit_pallas(logits, 0.6, interpret=True)
        hr, exr = ref.entropy_exit_ref(logits, 0.6)
        np.testing.assert_allclose(np.asarray(h), np.asarray(hr), **tol(dtype))
        # Flags may differ only for entropies within tolerance of the knife edge.
        diff = np.asarray(ex) != np.asarray(exr)
        assert np.all(np.abs(np.asarray(hr)[diff] - 0.6) < 1e-2)

    def test_threshold_semantics(self):
        # A delta distribution has ~zero entropy -> always exits.
        logits = jnp.full((2, 512), -30.0).at[:, 7].set(30.0)
        h, ex = entropy_exit_pallas(logits, 0.1, interpret=True)
        assert np.asarray(ex).all()
        # Uniform -> entropy 1 -> never exits.
        h, ex = entropy_exit_pallas(jnp.zeros((2, 512)), 0.99, interpret=True)
        assert np.allclose(np.asarray(h), 1.0, atol=1e-5)
        assert not np.asarray(ex).any()


class TestEntropyExitArgmax:
    """The fused exit-decision kernel: entropy + threshold flag + argmax
    token in one pass (the serving hot path's per-branch confidence test)."""

    @pytest.mark.parametrize("b,v", [(1, 128), (4, 1000), (8, 2048),
                                     (3, 5003), (16, 32064)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, b, v, dtype):
        key = jax.random.PRNGKey(b * v + 1)
        logits = (jax.random.normal(key, (b, v), jnp.float32) * 4).astype(dtype)
        h, ex, idx = entropy_exit_argmax_pallas(logits, 0.6, interpret=True)
        hr, exr, ir = ref.entropy_exit_argmax_ref(logits, 0.6)
        np.testing.assert_allclose(np.asarray(h), np.asarray(hr), **tol(dtype))
        # The token must be bitwise the jnp argmax — it is what the branch
        # emits on exit, and trajectory equivalence depends on it.
        np.testing.assert_array_equal(np.asarray(idx), np.asarray(ir))
        # Flags may differ only within tolerance of the knife edge.
        diff = np.asarray(ex) != np.asarray(exr)
        assert np.all(np.abs(np.asarray(hr)[diff] - 0.6) < 1e-2)

    def test_argmax_tie_breaks_first_occurrence(self):
        """Duplicated maxima inside one tile and across tiles both resolve
        to the first index, like jnp.argmax."""
        l = jnp.zeros((2, 4096)).at[:, 100].set(5.0).at[:, 3000].set(5.0)
        _, _, idx = entropy_exit_argmax_pallas(l, 0.5, interpret=True)
        np.testing.assert_array_equal(np.asarray(idx), [100, 100])
        l = jnp.zeros((1, 256)).at[0, 7].set(2.0).at[0, 9].set(2.0)
        _, _, idx = entropy_exit_argmax_pallas(l, 0.5, interpret=True)
        assert int(idx[0]) == 7

    def test_threshold_boundary_is_strict(self):
        """Regression (exit-threshold semantics): an entropy exactly AT the
        threshold does not exit — in the kernel, the ref oracle, and the
        serving inline computation alike (the decision is `H < t`)."""
        from repro.core.calibration import normalized_entropy

        logits = jax.random.normal(jax.random.PRNGKey(0), (4, 512)) * 3
        h_inline = normalized_entropy(logits)
        t = float(h_inline[1])  # sit exactly on row 1's entropy
        assert not bool(h_inline[1] < t)
        hr, exr = ref.entropy_exit_ref(logits, t)
        assert not bool(exr[1])
        h, ex, _ = entropy_exit_argmax_pallas(logits, t, interpret=True)
        # Kernel entropy may differ in the last ulp; the *semantics* are
        # strict-less-than against its own entropy value.
        assert not bool(h[1] < t) or abs(float(h[1]) - t) < 1e-6

    def test_normalization_matches_serving_inline(self):
        """Regression (log-base bugfix): the serving exit threshold
        (core.calibration.normalized_entropy), the kernel and the ref all
        normalize by log of the logits WIDTH in fp32 — including when the
        logits carry -1e30-masked vocab-padding lanes (padded_vocab_size),
        which contribute nothing to any accumulator."""
        from repro.core.calibration import normalized_entropy

        key = jax.random.PRNGKey(3)
        real = jax.random.normal(key, (5, 1000), jnp.float32) * 4
        padded = jnp.pad(real, ((0, 0), (0, 24)), constant_values=-1e30)
        h_inline = normalized_entropy(padded)
        hr, _ = ref.entropy_exit_ref(padded, 0.5)
        hk, _, _ = entropy_exit_argmax_pallas(padded, 0.5, interpret=True)
        assert h_inline.dtype == jnp.float32
        # Inline path and ref oracle are the same ops — exact agreement.
        np.testing.assert_array_equal(np.asarray(h_inline), np.asarray(hr))
        np.testing.assert_allclose(np.asarray(hk), np.asarray(hr),
                                   rtol=2e-6, atol=2e-6)
        # bf16 logits: the inline path must also run fp32 math (the bf16
        # softmax it used to do would disagree with the kernel at the
        # threshold knife edge).
        h_bf = normalized_entropy(real.astype(jnp.bfloat16))
        assert h_bf.dtype == jnp.float32


class TestSSDUpdate:
    """The single-step SSD decode kernel with the survivor row map."""

    @pytest.mark.parametrize(
        "bc,b,h,p,n,g",
        [
            (4, 4, 4, 64, 32, 4),  # rows=None full batch, G == H
            (6, 3, 4, 64, 32, 2),  # compacted sub-batch, grouped B/C
            (8, 2, 24, 64, 128, 1),  # mamba2-130m head shape, 1 group
            (5, 5, 2, 128, 64, 2),
        ],
    )
    def test_matches_ref(self, bc, b, h, p, n, g):
        ks = jax.random.split(jax.random.PRNGKey(bc * b + h), 5)
        hs = jax.random.normal(ks[0], (bc, h, p, n), jnp.float32)
        x = jax.random.normal(ks[1], (b, h, p)) * 0.5
        a = -jnp.abs(jax.random.normal(ks[2], (b, h))) * 0.3
        bv = jax.random.normal(ks[3], (b, g, n)) * 0.5
        cv = jax.random.normal(ks[4], (b, g, n)) * 0.5
        rows = None
        if b < bc:
            rows = jnp.asarray(
                np.random.default_rng(0).choice(bc, size=b, replace=False),
                jnp.int32,
            )
        y, hn = ssd_update_pallas(hs, x, a, bv, cv, rows, interpret=True)
        yr, hnr = ref.ssd_update_ref(hs, x, a, bv, cv, rows)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(hn), np.asarray(hnr),
                                   rtol=1e-5, atol=1e-5)

    def test_matches_model_ssd_step(self):
        """The kernel agrees with models.mamba.ssd_step on gathered rows —
        the jnp decode path it replaces."""
        from repro.models.mamba import ssd_step

        ks = jax.random.split(jax.random.PRNGKey(9), 5)
        bc, b, h, p, n, g = 6, 3, 4, 32, 16, 2
        hs = jax.random.normal(ks[0], (bc, h, p, n), jnp.float32)
        x = jax.random.normal(ks[1], (b, h, p)) * 0.5
        a = -jnp.abs(jax.random.normal(ks[2], (b, h))) * 0.3
        bv = jax.random.normal(ks[3], (b, g, n)) * 0.5
        cv = jax.random.normal(ks[4], (b, g, n)) * 0.5
        rows = jnp.asarray([5, 0, 3], jnp.int32)
        y_k, h_k = ssd_update_pallas(hs, x, a, bv, cv, rows, interpret=True)
        y_m, h_m = ssd_step(hs[rows], x, a, bv, cv)
        np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_m),
                                   rtol=1e-5, atol=1e-5)


class TestFlashDecode:
    @pytest.mark.parametrize(
        "b,h,kh,d,c,window,length",
        [
            (2, 8, 2, 128, 1024, 0, 700),
            (1, 4, 4, 64, 513, 0, 513),
            (3, 16, 4, 128, 2048, 256, 2048),
            (2, 8, 1, 128, 100, 0, 37),
            (1, 32, 8, 128, 4096, 1024, 4096),
        ],
    )
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, b, h, kh, d, c, window, length, dtype):
        ks = jax.random.split(jax.random.PRNGKey(length), 3)
        q = jax.random.normal(ks[0], (b, h, d), jnp.float32).astype(dtype)
        k = jax.random.normal(ks[1], (b, c, kh, d), jnp.float32).astype(dtype)
        v = jax.random.normal(ks[2], (b, c, kh, d), jnp.float32).astype(dtype)
        pos = np.full(c, -1, np.int32)
        pos[:length] = np.arange(length)
        pos = jnp.asarray(pos)
        qpos = jnp.asarray(length, jnp.int32)
        o = flash_decode_pallas(q, k, v, pos, qpos, window=window, interpret=True)
        r = ref.flash_decode_ref(q, k, v, pos, qpos, window=window)
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(r, np.float32), **tol(dtype))

    def test_per_sequence_slot_validity(self):
        """(B, C) k_pos: each sequence masks its own holes (the compacted
        runtime leaves -1 slots in rows that skipped a step downstream)."""
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        b, h, kh, d, c, length = 3, 8, 2, 64, 512, 300
        q = jax.random.normal(ks[0], (b, h, d))
        k = jax.random.normal(ks[1], (b, c, kh, d))
        v = jax.random.normal(ks[2], (b, c, kh, d))
        pos = np.full((b, c), -1, np.int32)
        rng = np.random.default_rng(7)
        for r in range(b):
            pos[r, :length] = np.arange(length)
            pos[r, rng.choice(length, size=40, replace=False)] = -1  # holes
        pos = jnp.asarray(pos)
        qpos = jnp.asarray(length, jnp.int32)
        o = flash_decode_pallas(q, k, v, pos, qpos, interpret=True)
        r = ref.flash_decode_ref(q, k, v, pos, qpos)
        np.testing.assert_allclose(np.asarray(o), np.asarray(r), rtol=2e-4,
                                   atol=2e-4)

    def test_survivor_row_map(self):
        """rows scalar-prefetch: a compacted sub-batch attends in place
        against survivor rows of a larger resident cache."""
        ks = jax.random.split(jax.random.PRNGKey(4), 3)
        bc, b, h, kh, d, c, length = 6, 2, 8, 2, 64, 256, 200
        q = jax.random.normal(ks[0], (b, h, d))
        k = jax.random.normal(ks[1], (bc, c, kh, d))
        v = jax.random.normal(ks[2], (bc, c, kh, d))
        pos = np.full((bc, c), -1, np.int32)
        pos[:, :length] = np.arange(length)
        pos = jnp.asarray(pos)
        qpos = jnp.asarray(length, jnp.int32)
        rows = jnp.asarray([5, 2], jnp.int32)
        o = flash_decode_pallas(q, k, v, pos, qpos, rows, interpret=True)
        r = ref.flash_decode_ref(q, k, v, pos, qpos, rows)
        np.testing.assert_allclose(np.asarray(o), np.asarray(r), rtol=2e-4,
                                   atol=2e-4)
        # Identical to gathering the cache rows up front.
        o2 = flash_decode_pallas(
            q, k[rows], v[rows], pos[rows], qpos, interpret=True
        )
        np.testing.assert_allclose(np.asarray(o), np.asarray(o2), rtol=1e-5,
                                   atol=1e-5)

    def test_ring_cache_order_irrelevant(self):
        """Attention must depend on stored positions, not slot order."""
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        b, h, kh, d, c = 1, 4, 2, 64, 64
        q = jax.random.normal(ks[0], (b, h, d))
        k = jax.random.normal(ks[1], (b, c, kh, d))
        v = jax.random.normal(ks[2], (b, c, kh, d))
        pos = jnp.arange(c)
        o1 = flash_decode_pallas(q, k, v, pos, jnp.asarray(c), interpret=True)
        perm = np.random.default_rng(0).permutation(c)
        o2 = flash_decode_pallas(
            q, k[:, perm], v[:, perm], pos[perm], jnp.asarray(c), interpret=True
        )
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5, atol=1e-5)


class TestSSDScan:
    @pytest.mark.parametrize(
        "b,l,h,p,n,chunk",
        [
            (2, 64, 4, 64, 32, 16),
            (1, 100, 2, 128, 64, 32),
            (2, 256, 3, 64, 128, 128),
            (1, 128, 24, 64, 128, 64),  # mamba2-130m block shape
        ],
    )
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_sequential_ref(self, b, l, h, p, n, chunk, dtype):
        ks = jax.random.split(jax.random.PRNGKey(l * h), 4)
        x = (jax.random.normal(ks[0], (b, l, h, p)) * 0.5).astype(dtype)
        a = -jnp.abs(jax.random.normal(ks[1], (b, l, h))) * 0.3
        bm = (jax.random.normal(ks[2], (b, l, h, n)) * 0.5).astype(dtype)
        cm = (jax.random.normal(ks[3], (b, l, h, n)) * 0.5).astype(dtype)
        y, hf = ssd_scan_pallas(x, a.astype(dtype), bm, cm, chunk=chunk,
                                interpret=True)
        yr, hr = ref.ssd_scan_ref(x, a.astype(dtype), bm, cm)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(yr, np.float32), **tol(dtype))
        np.testing.assert_allclose(np.asarray(hf), np.asarray(hr),
                                   **tol(dtype))

    def test_matches_model_ssd(self):
        """The kernel agrees with the model's jnp chunked implementation."""
        from repro.models.mamba import ssd_chunked

        ks = jax.random.split(jax.random.PRNGKey(5), 4)
        b, l, h, p, n = 2, 96, 4, 64, 32
        x = jax.random.normal(ks[0], (b, l, h, p)) * 0.5
        a = -jnp.abs(jax.random.normal(ks[1], (b, l, h))) * 0.3
        bm = jax.random.normal(ks[2], (b, l, h, n)) * 0.5
        cm = jax.random.normal(ks[3], (b, l, h, n)) * 0.5
        y_k, h_k = ssd_scan_pallas(x, a, bm, cm, chunk=32, interpret=True)
        y_m, h_m = ssd_chunked(x, a, bm, cm, chunk=32)
        np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_m),
                                   rtol=1e-4, atol=1e-4)
