"""Sharding policy unit tests — pure spec logic, no 512-device init
(the policy is exercised for real by launch/dryrun.py)."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_local_mesh
from repro.sharding.policy import ShardingPolicy


class FakeMesh:
    """Duck-typed mesh: policy only reads .shape (a dict)."""

    def __init__(self, **axes):
        self.shape = dict(axes)


def make_policy_for(cfg, **axes):
    return ShardingPolicy(
        mesh=FakeMesh(**axes), cfg=cfg,
        batch_axes=tuple(a for a in ("pod", "data") if a in axes),
    )


class TestParamSpecs:
    def test_attention_proj_sharded_on_model(self):
        cfg = get_config("qwen3_8b")
        pol = make_policy_for(cfg, data=16, model=16)
        spec = pol.param_spec("blocks/attn/wq", (36, 4096, 4096))
        assert spec[-1] == "model"
        spec = pol.param_spec("blocks/attn/wo", (36, 4096, 4096))
        assert spec[-2] == "model"

    def test_fsdp_shards_input_dim(self):
        cfg = get_config("phi3_medium_14b")  # fsdp=True
        pol = make_policy_for(cfg, data=16, model=16)
        spec = pol.param_spec("blocks/mlp/w_gate", (40, 5120, 17920))
        assert spec[-2] in ("data", ("data",))  # P normalizes 1-tuples
        assert spec[-1] == "model"

    def test_moe_expert_axis(self):
        cfg = get_config("deepseek_v3_671b")  # ships moe_fsdp_dim="ff" (§Perf)
        pol = make_policy_for(cfg, data=16, model=16)
        spec = pol.param_spec("blocks/moe/w_gate", (58, 256, 7168, 2048))
        assert spec[-3] == "model"  # experts
        assert spec[-1] in ("data", ("data",))  # fsdp on the ff dim
        spec_down = pol.param_spec("blocks/moe/w_down", (58, 256, 2048, 7168))
        assert spec_down[-2] in ("data", ("data",))  # ff dim of w_down

        import dataclasses
        cfg_d = dataclasses.replace(cfg, moe_fsdp_dim="d")  # paper-faithful baseline
        pol_d = make_policy_for(cfg_d, data=16, model=16)
        spec = pol_d.param_spec("blocks/moe/w_gate", (58, 256, 7168, 2048))
        assert spec[-2] in ("data", ("data",))  # fsdp on d

    def test_indivisible_falls_back_to_replicated(self):
        cfg = get_config("phi3_medium_14b")
        pol = make_policy_for(cfg, data=16, model=16)
        # kv = 10 heads * 128 = 1280; 1280 % 16 == 0 so wk IS shardable;
        # check a genuinely indivisible case instead: vocab 51865 (whisper).
        wcfg = get_config("whisper_medium")
        wpol = make_policy_for(wcfg, data=16, model=16)
        spec = wpol.param_spec("embed", (51865, 1024))
        assert spec[0] is None  # unpadded vocab cannot shard 16 ways

    def test_norms_replicated(self):
        cfg = get_config("olmo_1b")
        pol = make_policy_for(cfg, data=16, model=16)
        assert pol.param_spec("final_norm/scale", (2048,)) == P()


class TestCacheSpecs:
    def test_kv_heads_divisible(self):
        cfg = get_config("phi3_mini_3_8b")  # kv=32
        pol = make_policy_for(cfg, data=16, model=16)
        spec = pol.cache_spec("blocks/self/k", (32, 128, 32768, 32, 96))
        assert spec[-2] == "model"

    def test_kv_heads_indivisible_uses_head_dim(self):
        cfg = get_config("qwen3_8b")  # kv=8 < 16
        pol = make_policy_for(cfg, data=16, model=16)
        spec = pol.cache_spec("blocks/self/k", (36, 128, 32768, 8, 128))
        assert spec[-2] is None and spec[-1] == "model"

    def test_mla_latent_sharded(self):
        cfg = get_config("deepseek_v3_671b")
        pol = make_policy_for(cfg, data=16, model=16)
        spec = pol.cache_spec("blocks/self/ckv", (61, 128, 32768, 512))
        assert spec[-1] == "model"

    def test_batch_one_replicates(self):
        cfg = get_config("qwen3_8b")
        pol = make_policy_for(cfg, data=16, model=16)
        spec = pol.cache_spec("blocks/self/k", (36, 1, 8192, 8, 128))
        assert spec[1] is None  # long_500k: batch 1 cannot shard


class TestDataSpecs:
    def test_batch_prefix(self):
        cfg = get_config("qwen3_8b")
        pol = make_policy_for(cfg, pod=2, data=16, model=16)
        assert pol.data_spec((256, 4096)) == P(("pod", "data"), None)
        # batch 16: 16 % 2 == 0 but 16 % 32 != 0 -> only the pod prefix.
        spec = pol.data_spec((16, 4096))
        assert spec[0] in ("pod", ("pod",), ("pod", "data"))

    def test_opt_state_shardings_structure(self):
        cfg = get_config("qwen3_8b")
        pol = make_policy_for(cfg, data=16, model=16)
        # Build against real abstract params on the local mesh is heavy;
        # just verify the adafactor reducer logic on a toy tree.
        import jax

        shapes = {"w": jax.ShapeDtypeStruct((64, 32), np.float32)}
        # adamw mirrors params:
        with pytest.raises(Exception):
            # NamedSharding construction needs a real Mesh; FakeMesh fails —
            # the real path is covered by the dry-run.
            pol.opt_state_shardings(shapes, "adamw")


def _axes_size(mesh_shape: dict, entry) -> int:
    """Product of the mesh-axis sizes named by one PartitionSpec entry."""
    if entry is None:
        return 1
    if isinstance(entry, str):
        return mesh_shape[entry]
    size = 1
    for a in entry:
        size *= mesh_shape[a]
    return size


def _assert_spec_divides(mesh_shape, spec, shape, path):
    # A PartitionSpec may be shorter than the rank: trailing dims replicate.
    assert len(spec) <= len(shape), f"{path}: over-rank {spec} vs {shape}"
    spec = tuple(spec) + (None,) * (len(shape) - len(spec))
    for d, (entry, dim) in enumerate(zip(spec, shape)):
        size = _axes_size(mesh_shape, entry)
        assert dim % size == 0, (
            f"{path} dim {d} ({dim}) not divisible by {entry} ({size}); "
            "the rule must fall back to replication"
        )


def _tree_paths(shapes):
    from repro.sharding.policy import _key_str

    return [
        ("/".join(_key_str(k) for k in keypath), leaf)
        for keypath, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]
    ]


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestAllConfigsDivisibility:
    """Every rule in the policy either shards a dim cleanly or replicates
    it — across all ten shipped configs (phi3-medium's kv=10 heads,
    whisper's unpadded 51865 vocab, the MoE expert dims).  Shape trees
    come from ``jax.eval_shape`` so the 671B config costs nothing."""

    MESH = dict(data=16, model=16)

    def _policy(self, cfg):
        return make_policy_for(cfg, **self.MESH)

    def test_param_specs_shard_or_replicate(self, arch):
        from repro.models import model as M

        cfg = get_config(arch)
        pol = self._policy(cfg)
        shapes = jax.eval_shape(
            lambda k: M.init_params(k, cfg), jax.random.PRNGKey(0)
        )
        flat = _tree_paths(shapes)
        assert flat
        for path, leaf in flat:
            spec = pol.param_spec(path, leaf.shape)
            _assert_spec_divides(self.MESH, spec, leaf.shape, path)

    def test_cache_specs_shard_or_replicate(self, arch):
        from repro.models import model as M

        cfg = get_config(arch)
        pol = self._policy(cfg)
        shapes = jax.eval_shape(lambda: M.init_caches(cfg, 16, 256))
        flat = _tree_paths(shapes)
        assert flat
        for path, leaf in flat:
            spec = pol.cache_spec(path, leaf.shape)
            _assert_spec_divides(self.MESH, spec, leaf.shape, path)
