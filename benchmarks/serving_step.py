"""Serving decode-step micro-benchmark: host syncs + wall time.

Before the unified tier runtime, every decode step crossed the device
boundary once per side branch *twice* (entropy fetch + exit-count fetch)
plus once for the survivor count and once for the tokens — the legacy loop
below reproduces that pattern.  The fused runtime keeps exit masking
device-resident and performs exactly ONE device->host sync per step; this
benchmark measures both and asserts the invariant the tests rely on.

Run:  PYTHONPATH=src python benchmarks/serving_step.py
"""

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serving import PartitionedServer

BATCH = 8
CONTEXT = 128
STEPS = 32
WARMUP = 4


class SyncCounter:
    """Counts device->host fetches the way the legacy loop caused them."""

    def __init__(self):
        self.count = 0

    def __call__(self, x):
        self.count += 1
        return np.asarray(x)


def legacy_step(decode, params, cfg, tok, pos, caches, sync):
    """The pre-refactor decode step: monolithic jitted forward, then
    per-branch host round trips for entropy logging, exit counting, and
    selection."""
    out = decode(params, tok, jnp.asarray(pos, jnp.int32), caches)
    chosen = jnp.argmax(out["logits"], -1).astype(jnp.int32)
    exited = jnp.zeros(chosen.shape, bool)
    for layer in cfg.branch_layers:
        sync(out["branch_entropy"][layer])  # stats logging fetch
        b_tok = jnp.argmax(out["branch_logits"][layer], -1).astype(jnp.int32)
        take = out["branch_exit"][layer] & ~exited
        int(sync(take).sum())  # per-branch exit count fetch
        chosen = jnp.where(take, b_tok, chosen)
        exited = exited | out["branch_exit"][layer]
    int(sync(~exited).sum())  # survivor count fetch
    toks = sync(chosen)  # token fetch
    return toks, out["caches"]


def run_legacy(cfg, params):
    decode = jax.jit(
        lambda params, tok, pos, caches: M.decode_step(params, tok, pos,
                                                       caches, cfg)
    )
    sync = SyncCounter()
    caches = M.init_caches(cfg, BATCH, CONTEXT)
    tok = jnp.zeros((BATCH, 1), jnp.int32)
    for i in range(WARMUP):
        toks, caches = legacy_step(decode, params, cfg, tok, i, caches,
                                   SyncCounter())
        tok = jnp.asarray(toks[:, None])
    t0 = time.perf_counter()
    for i in range(WARMUP, WARMUP + STEPS):
        toks, caches = legacy_step(decode, params, cfg, tok, i, caches, sync)
        tok = jnp.asarray(toks[:, None])
    dt = time.perf_counter() - t0
    return dt / STEPS, sync.count / STEPS


def run_fused(cfg, params, split):
    srv = PartitionedServer(cfg, params, split)
    caches = M.init_caches(cfg, BATCH, CONTEXT)
    tok = jnp.zeros((BATCH, 1), jnp.int32)
    for i in range(WARMUP):
        rep, caches = srv.step(tok, i, caches)
        tok = jnp.asarray(rep.tokens[:, None])
    start_syncs = srv.executor.host_syncs
    t0 = time.perf_counter()
    for i in range(WARMUP, WARMUP + STEPS):
        rep, caches = srv.step(tok, i, caches)
        tok = jnp.asarray(rep.tokens[:, None])
    dt = time.perf_counter() - t0
    return dt / STEPS, (srv.executor.host_syncs - start_syncs) / STEPS


def main() -> None:
    cfg = dataclasses.replace(
        get_smoke_config("qwen3_8b"), num_layers=4, branch_layers=(1, 3)
    )
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    total = cfg.num_layers
    print(f"{cfg.name} (reduced): {cfg.num_layers} layers, "
          f"branches {cfg.branch_layers}, batch {BATCH}")

    t_old, s_old = run_legacy(cfg, params)
    # Like-for-like wall-time comparison: edge-only (split == L) evaluates
    # the same branch set + final head as the legacy monolithic loop, so
    # the delta is sync elimination, not skipped branch compute.
    t_new, s_new = run_fused(cfg, params, total)
    # The shipped configuration: a mid split (the cloud tier evaluates no
    # branches, so its compute differs from legacy — sync count is the
    # comparable number here, not wall time).
    t_mid, s_mid = run_fused(cfg, params, 2)

    print(f"\n{'path':<30}{'ms/step':>10}{'host syncs/step':>18}")
    print(f"{'legacy per-branch loop':<30}{t_old * 1e3:>10.3f}{s_old:>18.1f}")
    print(f"{'fused runtime (edge-only)':<30}{t_new * 1e3:>10.3f}{s_new:>18.1f}")
    print(f"{'fused runtime (split=2)':<30}{t_mid * 1e3:>10.3f}{s_mid:>18.1f}")
    print(f"\nlike-for-like speedup {t_old / t_new:.2f}x, "
          f"syncs {s_old:.0f} -> {s_new:.0f}")

    # The invariant the serving tests and ROADMAP claim: one sync per step,
    # at every split configuration.
    assert s_new == 1.0, f"fused path must do exactly 1 sync/step, got {s_new}"
    assert s_mid == 1.0, f"fused path must do exactly 1 sync/step, got {s_mid}"
    assert s_old >= 2 + 2 * len(cfg.branch_layers) - 1e-9
    print("OK: fused partitioned decode performs exactly 1 host sync/step")


if __name__ == "__main__":
    main()
