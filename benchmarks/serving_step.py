"""Serving decode-step benchmark: host syncs, wall time, a roofline-style
masked-vs-compacted sweep, and a serial-vs-pipelined overlap cell.

Part 1 (legacy vs fused): before the unified tier runtime, every decode
step crossed the device boundary once per side branch *twice* (entropy
fetch + exit-count fetch) plus once for the survivor count and once for
the tokens — the legacy loop below reproduces that pattern.  The fused
runtime keeps exit masking device-resident and performs exactly ONE
device->host sync per step.

Part 2 (roofline sweep): across batch size x split point x exit regime,
compare the masked runtime (every tier computes the full batch) against
the survivor-compacted runtime (downstream tiers compute a dense
sub-batch padded to the bucket ladder).  Reported downstream FLOPs/step
are analytic (2 * active params per layer per row * rows), so the sweep
shows the *shape* win even on CPU where wall time is noisy; syncs/step
and retry counts come from the executor's own counters.

Part 3 (overlap pipeline): under ``simulate_network=True`` with a
transfer-dominated K=3 profile, the serial runtime pays the chain sum
``compute + sum_j(transfer_j)`` per decode step while
``overlap="pipelined"`` pays the bottleneck stage
``max_j(compute_j, transfer_j)``; the cell asserts pipelined <= serial,
that the pipelined wall time agrees with
``expected_time_multitier(..., overlap=True)``, and that the cost model's
optimal cut *moves* when solved under overlap (the plan flip that
motivates re-solving on pipelined deployments).

Part 4 (continuous batching): a Poisson-arrival stream of mixed prompt
lengths and token budgets with early exits enabled, served twice through
the SAME warmed server — once with gang (lock-step wave) admission, once
with continuous admission into recycled KV slots.  Continuous batching
retires finished/early-exited requests mid-flight and prefill-admits the
queue into the freed rows, so the same useful tokens take fewer decode
steps: the cell reports tokens/sec and p50/p95 TTFT per policy and
asserts continuous > lock-step throughput at one host sync per decode
step.

Part 5 (faults): a scripted mid-run link flap on hop 1 of a K=3 serving
stack with the fault plane armed (seeded LinkFaultModel + HopPolicy) and
a RepartitionController ingesting hop health.  Retries exhaust, the
breaker opens, survivors finalize from the deepest exit head below the
broken hop (degraded tokens — still real tokens), and the controller
re-solves to cuts that ship zero bytes on the sick link.  The cell
reports tokens/sec, the degraded-token fraction, and the fault re-solve
count, and asserts every request completes with no leaked KV slots.

Run:  PYTHONPATH=src python benchmarks/serving_step.py
Fast CI smoke:  REPRO_BENCH_FAST=1 PYTHONPATH=src python benchmarks/serving_step.py
Overlap cell only:  REPRO_BENCH_ONLY=overlap PYTHONPATH=src python benchmarks/serving_step.py
Request cell only:  REPRO_BENCH_ONLY=requests PYTHONPATH=src python benchmarks/serving_step.py
Fault cell only:  REPRO_BENCH_ONLY=faults PYTHONPATH=src python benchmarks/serving_step.py
"""

import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from bench_io import BenchBundle
from repro.configs import get_smoke_config
from repro.core import LayerCost, build_cost_profile
from repro.core.multitier import TierSpec, expected_time_multitier, solve_multitier
from repro.models import model as M
from repro.serving import (
    FlapWindow,
    HopPolicy,
    LinkFaultModel,
    MultiTierServer,
    PartitionedServer,
    RepartitionController,
    RequestScheduler,
)

FAST = bool(int(os.environ.get("REPRO_BENCH_FAST", "0")))
ONLY = os.environ.get("REPRO_BENCH_ONLY", "")

CONTEXT = 128
STEPS = 8 if FAST else 32
WARMUP = 2 if FAST else 4
BATCH = 8  # part-1 batch
SWEEP_BATCHES = (8,) if FAST else (8, 16)
SWEEP_SPLITS = (2,) if FAST else (1, 2, 3)
#: exit regimes: threshold -> expected exit-rate band
REGIMES = (
    (("all-exit", 1.5),) if FAST
    else (("no-exit", 0.0), ("all-exit", 1.5))
)


class SyncCounter:
    """Counts device->host fetches the way the legacy loop caused them."""

    def __init__(self):
        self.count = 0

    def __call__(self, x):
        self.count += 1
        return np.asarray(x)


def legacy_step(decode, params, cfg, tok, pos, caches, sync):
    """The pre-refactor decode step: monolithic jitted forward, then
    per-branch host round trips for entropy logging, exit counting, and
    selection."""
    out = decode(params, tok, jnp.asarray(pos, jnp.int32), caches)
    chosen = jnp.argmax(out["logits"], -1).astype(jnp.int32)
    exited = jnp.zeros(chosen.shape, bool)
    for layer in cfg.branch_layers:
        sync(out["branch_entropy"][layer])  # stats logging fetch
        b_tok = jnp.argmax(out["branch_logits"][layer], -1).astype(jnp.int32)
        take = out["branch_exit"][layer] & ~exited
        int(sync(take).sum())  # per-branch exit count fetch
        chosen = jnp.where(take, b_tok, chosen)
        exited = exited | out["branch_exit"][layer]
    int(sync(~exited).sum())  # survivor count fetch
    toks = sync(chosen)  # token fetch
    return toks, out["caches"]


def run_legacy(cfg, params):
    decode = jax.jit(
        lambda params, tok, pos, caches: M.decode_step(params, tok, pos,
                                                       caches, cfg)
    )
    sync = SyncCounter()
    caches = M.init_caches(cfg, BATCH, CONTEXT)
    tok = jnp.zeros((BATCH, 1), jnp.int32)
    for i in range(WARMUP):
        toks, caches = legacy_step(decode, params, cfg, tok, i, caches,
                                   SyncCounter())
        tok = jnp.asarray(toks[:, None])
    t0 = time.perf_counter()
    for i in range(WARMUP, WARMUP + STEPS):
        toks, caches = legacy_step(decode, params, cfg, tok, i, caches, sync)
        tok = jnp.asarray(toks[:, None])
    dt = time.perf_counter() - t0
    return dt / STEPS, sync.count / STEPS


def run_fused(cfg, params, split, *, batch=BATCH, compaction="bucketed",
              steps=STEPS, warmup=WARMUP):
    """Returns (ms/step, syncs/step, retries, mean survivors, mean bucket,
    mean exit rate) over the measured steps."""
    srv = PartitionedServer(cfg, params, split, compaction=compaction)
    caches = M.init_caches(cfg, batch, CONTEXT)
    tok = jnp.zeros((batch, 1), jnp.int32)
    for i in range(warmup):
        rep, caches = srv.step(tok, i, caches)
        tok = jnp.asarray(rep.tokens[:, None])
    start_syncs = srv.executor.host_syncs
    start_retries = srv.executor.overflow_retries
    surv, buck, exit_rate = [], [], []
    t0 = time.perf_counter()
    for i in range(warmup, warmup + steps):
        rep, caches = srv.step(tok, i, caches)
        tok = jnp.asarray(rep.tokens[:, None])
        exit_rate.append(float(rep.exited_on_edge.mean()))
        if rep.compaction:
            surv.append(rep.compaction[0].survivors)
            buck.append(rep.compaction[0].bucket)
    dt = time.perf_counter() - t0
    return (
        dt / steps * 1e3,
        (srv.executor.host_syncs - start_syncs) / steps,
        srv.executor.overflow_retries - start_retries,
        float(np.mean(surv)) if surv else 0.0,
        float(np.mean(buck)) if buck else 0.0,
        float(np.mean(exit_rate)),
    )


def downstream_flops_per_row(cfg, split):
    """Analytic decode FLOPs per sequence-row for the layers after the
    split (2 FLOPs per MAC on the active matmul params, shared with
    ModelConfig's parameter accounting)."""
    assert cfg.arch_type in ("dense", "vlm"), (
        "per-row FLOPs formula only covers dense trunks; extend via "
        "ModelConfig helpers before sweeping other arch types"
    )
    per_layer = cfg.attn_matmul_params() + cfg.dense_mlp_matmul_params()
    layers_dn = cfg.num_layers - split
    head = cfg.d_model * cfg.padded_vocab_size if layers_dn > 0 else 0
    return 2.0 * (layers_dn * per_layer + head)


def part1_legacy_vs_fused(cfg, params, bundle):
    total = cfg.num_layers
    t_old, s_old = run_legacy(cfg, params)
    # Like-for-like wall-time comparison: edge-only (split == L) evaluates
    # the same branch set + final head as the legacy monolithic loop, so
    # the delta is sync elimination, not skipped branch compute.
    t_new, s_new, r_new, *_ = run_fused(cfg, params, total)
    # The shipped configuration: a mid split (the cloud tier evaluates no
    # branches, so its compute differs from legacy — sync count is the
    # comparable number here, not wall time).
    t_mid, s_mid, r_mid, *_ = run_fused(cfg, params, 2)

    print(f"\n{'path':<30}{'ms/step':>10}{'host syncs/step':>18}")
    print(f"{'legacy per-branch loop':<30}{t_old * 1e3:>10.3f}{s_old:>18.1f}")
    print(f"{'fused runtime (edge-only)':<30}{t_new:>10.3f}{s_new:>18.1f}")
    print(f"{'fused runtime (split=2)':<30}{t_mid:>10.3f}{s_mid:>18.1f}")
    print(f"\nlike-for-like speedup {t_old * 1e3 / t_new:.2f}x, "
          f"syncs {s_old:.0f} -> {s_new:.0f}")

    # The invariant the serving tests and ROADMAP claim: one sync per
    # decode step.  Overflow-retry steps legitimately pay one extra
    # (counted) sync, so the assertion is exact accounting, not a flake:
    # syncs == steps + retries, with retries == 0 in the steady state here.
    assert s_new == 1.0 + r_new / STEPS, (
        f"edge-only: {s_new} syncs/step with {r_new} retries")
    assert s_mid == 1.0 + r_mid / STEPS, (
        f"split=2: {s_mid} syncs/step with {r_mid} retries")
    assert s_old >= 2 + 2 * len(cfg.branch_layers) - 1e-9
    print(f"OK: fused partitioned decode performs exactly 1 host sync/step "
          f"(+{r_new + r_mid} overflow retries)")
    bundle.cell(
        "legacy_vs_fused",
        config=dict(batch=BATCH, steps=STEPS, fast=FAST),
        strict=dict(
            legacy_syncs_per_step=s_old,
            fused_edge_syncs_per_step=s_new,
            fused_split2_syncs_per_step=s_mid,
            overflow_retries=r_new + r_mid,
        ),
        timing=dict(
            legacy_ms_step=t_old * 1e3,
            fused_edge_ms_step=t_new,
            fused_split2_ms_step=t_mid,
        ),
    )


def part2_roofline_sweep(cfg0, params, bundle):
    print("\n== roofline sweep: masked vs survivor-compacted downstream "
          "FLOPs/step ==")
    hdr = (f"{'B':>3} {'split':>5} {'regime':>9} {'exit%':>6} "
           f"{'surv':>5} {'bucket':>6} "
           f"{'GF/step masked':>15} {'GF/step compact':>16} {'save':>6} "
           f"{'ms mask':>8} {'ms comp':>8} {'syncs':>6} {'retry':>6}")
    print(hdr)
    checked_50 = False
    for batch in SWEEP_BATCHES:
        for split in SWEEP_SPLITS:
            for name, thr in REGIMES:
                cfg = dataclasses.replace(cfg0, exit_threshold=thr)
                t_m, s_m, _, _, _, _ = run_fused(
                    cfg, params, split, batch=batch, compaction="off",
                    steps=max(4, STEPS // 2), warmup=WARMUP,
                )
                (t_c, s_c, retries, surv, buck, exit_rate) = run_fused(
                    cfg, params, split, batch=batch,
                    steps=max(4, STEPS // 2), warmup=WARMUP,
                )
                fpr = downstream_flops_per_row(cfg, split)
                gf_masked = fpr * batch / 1e9
                gf_comp = fpr * (buck if buck else batch) / 1e9
                save = 1.0 - gf_comp / gf_masked if gf_masked else 0.0
                print(f"{batch:>3} {split:>5} {name:>9} "
                      f"{exit_rate * 100:>5.0f}% {surv:>5.1f} {buck:>6.1f} "
                      f"{gf_masked:>15.3f} {gf_comp:>16.3f} "
                      f"{save * 100:>5.0f}% {t_m:>8.2f} {t_c:>8.2f} "
                      f"{s_c:>6.2f} {retries:>6}")
                bundle.cell(
                    f"roofline_b{batch}_s{split}_{name}",
                    config=dict(batch=batch, split=split, regime=name,
                                fast=FAST),
                    strict=dict(
                        exit_rate=round(exit_rate, 6),
                        survivors=surv, bucket=buck,
                        gf_step_masked=round(gf_masked, 6),
                        gf_step_compact=round(gf_comp, 6),
                        syncs_per_step=s_c, overflow_retries=retries,
                    ),
                    timing=dict(ms_step_masked=t_m, ms_step_compact=t_c),
                )
                assert s_m == 1.0, "masked path must stay at 1 sync/step"
                # Acceptance: at exit rates >= 0.5 the downstream tier's
                # FLOPs scale with the padded survivor count, not with B.
                if exit_rate >= 0.5 and split < cfg.num_layers:
                    assert gf_comp <= gf_masked / 2 + 1e-9, (
                        f"expected >=2x downstream FLOPs saving at exit rate "
                        f"{exit_rate:.2f}: masked {gf_masked}, compacted {gf_comp}"
                    )
                    checked_50 = True
    if checked_50:
        print("OK: downstream FLOPs scale with padded survivors "
              "(>=2x saving at exit rate >= 0.5)")


def _plan_flip_cell() -> dict:
    """Cost-model cell (no wall clock): on a profile whose transfers shrink
    with depth, the serial optimum hides on the edge (ship nothing) while
    the overlap optimum moves the cut forward — transfers below the
    bottleneck stage are free when pipelined."""
    t_c = np.array([0.0, 0.01, 0.01, 0.01, 0.01])
    alpha = np.array([80e3, 40e3, 20e3, 10e3, 5e3])
    p = np.zeros(5)
    tiers = [TierSpec("edge", 2.0, 4e6), TierSpec("cloud", 1.0)]
    print(f"\n{'cut':>4} {'serial ms':>10} {'pipelined ms':>13}")
    for s in range(len(t_c)):
        ser = expected_time_multitier(t_c, alpha, p, tiers, (s,))
        ovl = expected_time_multitier(t_c, alpha, p, tiers, (s,), overlap=True)
        print(f"{s:>4} {ser * 1e3:>10.1f} {ovl * 1e3:>13.1f}")
    plan_s = solve_multitier(t_c, alpha, p, tiers)
    plan_o = solve_multitier(t_c, alpha, p, tiers, overlap=True)
    print(f"serial plan: cut {plan_s.cut_after} "
          f"(E[T] {plan_s.expected_time_s * 1e3:.1f} ms) -> "
          f"pipelined plan: cut {plan_o.cut_after} "
          f"(E[T]/step {plan_o.expected_time_s * 1e3:.1f} ms)")
    assert plan_o.cut_after != plan_s.cut_after, (
        "expected the optimal cut to move under overlap on this profile"
    )
    assert plan_o.expected_time_s <= plan_s.expected_time_s + 1e-12
    print("OK: the optimal cut moves when transfers overlap compute")
    return dict(
        serial_cut=list(plan_s.cut_after),
        pipelined_cut=list(plan_o.cut_after),
        serial_est_ms=round(plan_s.expected_time_s * 1e3, 6),
        pipelined_est_ms=round(plan_o.expected_time_s * 1e3, 6),
    )


def _run_overlap(cfg, params, tiers, cuts, overlap, *, batch, steps, warmup):
    """Measured ms/step of a simulated-uplink K=3 server; the pipelined
    variant's trailing transfers are drained inside the timed region so
    both modes account for identical total work."""
    srv = MultiTierServer(
        cfg, params, tiers, cuts, simulate_network=True, overlap=overlap
    )
    caches = M.init_caches(cfg, batch, CONTEXT)
    tok = jnp.zeros((batch, 1), jnp.int32)
    for i in range(warmup):
        rep, caches = srv.step(tok, i, caches)
        tok = jnp.asarray(rep.tokens[:, None])
    srv.executor.drain()
    t0 = time.perf_counter()
    for i in range(warmup, warmup + steps):
        rep, caches = srv.step(tok, i, caches)
        tok = jnp.asarray(rep.tokens[:, None])
    srv.executor.drain()
    dt = time.perf_counter() - t0
    return dt / steps * 1e3, rep.sim_transfer_s


def part3_overlap_pipeline(cfg0, params, bundle):
    print("\n== overlap cell: serial vs pipelined tier runtime "
          "(simulate_network=True) ==")
    flip = _plan_flip_cell()

    # Transfer-dominated K=3 smoke: no exits, so every sequence crosses
    # both hops and the transfer sizes are deterministic.
    cfg = dataclasses.replace(cfg0, exit_threshold=0.0)
    batch = BATCH
    steps = 6 if FAST else 12
    per_seq = cfg.d_model * 2.0
    hop_s = (0.09, 0.05)  # target per-hop transfer seconds at full batch
    tiers = [
        TierSpec("device", 1.0, per_seq * batch * 8.0 / hop_s[0]),
        TierSpec("edge", 1.0, per_seq * batch * 8.0 / hop_s[1]),
        TierSpec("cloud", 1.0),
    ]
    cuts = (2, 3)
    t_serial, sim = _run_overlap(
        cfg, params, tiers, cuts, "serial",
        batch=batch, steps=steps, warmup=WARMUP,
    )
    t_pipe, _ = _run_overlap(
        cfg, params, tiers, cuts, "pipelined",
        batch=batch, steps=steps, warmup=WARMUP,
    )
    # Compute-only baseline calibrates the cost model's t_c (uniform
    # per-layer split of the measured masked step on this host).
    srv = MultiTierServer(cfg, params, tiers, cuts)
    caches = M.init_caches(cfg, batch, CONTEXT)
    tok = jnp.zeros((batch, 1), jnp.int32)
    for i in range(WARMUP):
        rep, caches = srv.step(tok, i, caches)
        tok = jnp.asarray(rep.tokens[:, None])
    t0 = time.perf_counter()
    for i in range(WARMUP, WARMUP + steps):
        rep, caches = srv.step(tok, i, caches)
        tok = jnp.asarray(rep.tokens[:, None])
    t_comp = (time.perf_counter() - t0) / steps

    n = cfg.num_layers
    t_c = np.concatenate([[0.0], np.full(n, t_comp / n)])
    alpha = np.full(n + 1, per_seq * batch)  # full batch crosses every hop
    p = np.zeros(n + 1)
    est_serial = expected_time_multitier(t_c, alpha, p, tiers, cuts)
    est_pipe = expected_time_multitier(t_c, alpha, p, tiers, cuts,
                                       overlap=True)
    print(f"\n{'mode':<12} {'ms/step':>9} {'est ms/step':>12} "
          f"(hop transfers {tuple(round(s * 1e3) for s in sim)} ms)")
    print(f"{'serial':<12} {t_serial:>9.1f} {est_serial * 1e3:>12.1f}")
    print(f"{'pipelined':<12} {t_pipe:>9.1f} {est_pipe * 1e3:>12.1f}")

    assert t_pipe <= t_serial, (
        f"pipelined steady-state step ({t_pipe:.1f} ms) must not exceed "
        f"serial ({t_serial:.1f} ms)"
    )
    # The pipelined wall time tracks the bottleneck stage, not the serial
    # sum: agreement with the overlap cost model within a pipeline-fill
    # tolerance (compute overhead + the non-bottleneck hop's tail).
    slack = 1e3 * (t_comp + min(hop_s)) + 0.25 * est_pipe * 1e3
    assert abs(t_pipe - est_pipe * 1e3) <= slack, (
        f"pipelined {t_pipe:.1f} ms/step vs overlap estimate "
        f"{est_pipe * 1e3:.1f} ms/step (slack {slack:.1f})"
    )
    assert t_serial >= est_pipe * 1e3  # serial pays at least the bottleneck
    print(f"OK: pipelined step tracks max_j(compute_j, transfer_j) "
          f"({t_pipe:.1f} ms vs est {est_pipe * 1e3:.1f} ms; serial pays "
          f"{t_serial:.1f} ms)")
    bundle.cell(
        "overlap_pipeline",
        config=dict(batch=batch, steps=steps, cuts=list(cuts),
                    hop_s=list(hop_s), fast=FAST),
        strict=flip,
        timing=dict(serial_ms_step=t_serial, pipelined_ms_step=t_pipe,
                    est_pipelined_ms_step=est_pipe * 1e3),
    )


def _mixed_threshold(cfg, params, batch=8):
    """Threshold between observed branch entropies -> deterministic mixed
    exits on the fixed seed (some tokens exit early, some don't)."""
    srv = PartitionedServer(cfg, params, cfg.num_layers)
    caches = M.init_caches(cfg, batch, CONTEXT)
    tok = jnp.zeros((batch, 1), jnp.int32)
    rep, _ = srv.step(tok, 0, caches)
    ents = np.concatenate(
        [rep.tier_result.branch_entropy[l] for l in cfg.branch_layers]
    )
    return float((ents.min() + ents.max()) / 2)


def _request_workload(cfg, n, seed=0):
    """Poisson arrivals (1 per step on average), mixed prompt lengths and
    budgets, half the requests retiring at their first early exit."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0, size=n)).astype(int)
    work = []
    for i in range(n):
        plen = int(rng.choice((4, 8)))
        prompt = rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32)
        work.append(dict(
            prompt=prompt,
            max_new_tokens=int(rng.integers(2, 9)),
            stop_on_exit=bool(i % 2),
            arrival_step=int(arrivals[i]),
        ))
    return work


def _run_requests(srv, slots, work, policy):
    """Serve the workload through a fresh scheduler on the (shared, warm)
    server; returns (steps, wall_s, tokens, ttft list, sync delta, retry
    delta)."""
    sched = RequestScheduler(srv, slots, CONTEXT, policy=policy)
    syncs0 = srv.executor.host_syncs
    retries0 = srv.executor.overflow_retries
    t0 = time.perf_counter()
    for w in work:
        sched.submit(w["prompt"], w["max_new_tokens"],
                     stop_on_exit=w["stop_on_exit"],
                     arrival_step=w["arrival_step"])
    results = sched.drain()
    dt = time.perf_counter() - t0
    ttfts = [r.ttft_s for r in results]
    return (
        sched.decode_steps, dt, sched.total_tokens, ttfts,
        srv.executor.host_syncs - syncs0,
        srv.executor.overflow_retries - retries0,
    )


def part4_continuous_batching(cfg0, params, bundle):
    print("\n== continuous batching: lock-step (gang) waves vs request "
          "admission into recycled KV slots ==")
    cfg = dataclasses.replace(
        cfg0, exit_threshold=_mixed_threshold(cfg0, params)
    )
    slots = 4 if FAST else 8
    n_req = 10 if FAST else 32
    srv = PartitionedServer(cfg, params, 2, slots=slots, context_len=CONTEXT)
    work = _request_workload(cfg, n_req)
    # Warm every (prompt-len, group) prefill shape and decode bucket once:
    # both policies then run all-compiled on the shared executor cache.
    for policy in ("gang", "continuous"):
        _run_requests(srv, slots, work, policy)

    rows = {}
    for policy in ("gang", "continuous"):
        # Best-of-2 timed passes: the step-count win is deterministic,
        # the wall-clock one shouldn't flake on a noisy CI runner.
        best = None
        for _ in range(2):
            r = _run_requests(srv, slots, work, policy)
            if best is None or r[1] < best[1]:
                best = r
        rows[policy] = best
    print(f"\n{'policy':<12} {'steps':>6} {'tokens':>7} {'tok/s':>8} "
          f"{'p50 TTFT ms':>12} {'p95 TTFT ms':>12} {'syncs/step':>11}")
    for policy, (steps, dt, toks, ttfts, syncs, retries) in rows.items():
        print(f"{policy:<12} {steps:>6} {toks:>7} {toks / dt:>8.1f} "
              f"{np.percentile(ttfts, 50) * 1e3:>12.1f} "
              f"{np.percentile(ttfts, 95) * 1e3:>12.1f} "
              f"{syncs / max(steps, 1):>11.2f}")

    g_steps, g_dt, g_toks, _, g_syncs, g_retries = rows["gang"]
    c_steps, c_dt, c_toks, _, c_syncs, c_retries = rows["continuous"]
    assert g_toks == c_toks, "both policies decode the same useful tokens"
    assert c_steps < g_steps, (
        f"continuous admission must need fewer decode steps "
        f"({c_steps} vs {g_steps})"
    )
    assert c_toks / c_dt > g_toks / g_dt, (
        f"continuous batching must beat lock-step throughput "
        f"({c_toks / c_dt:.1f} vs {g_toks / g_dt:.1f} tok/s)"
    )
    # The decode loop's contract survives admission/retirement churn:
    # exactly one device->host sync per decode step (+ counted retries).
    assert c_syncs == c_steps + c_retries, (
        f"continuous loop: {c_syncs} syncs over {c_steps} steps "
        f"({c_retries} retries)"
    )
    print(f"OK: continuous admission decodes the same {c_toks} tokens in "
          f"{c_steps} steps vs lock-step's {g_steps} "
          f"({c_toks / c_dt / (g_toks / g_dt):.2f}x tokens/sec) at 1 "
          f"sync/step")
    for policy, (steps, dt, toks, ttfts, syncs, retries) in rows.items():
        bundle.cell(
            f"requests_{policy}",
            config=dict(slots=slots, requests=n_req, fast=FAST),
            strict=dict(
                decode_steps=steps, tokens=toks,
                syncs_per_step=round(syncs / max(steps, 1), 6),
                overflow_retries=retries,
            ),
            timing=dict(
                tokens_per_s=toks / dt,
                ttft_p50_ms=float(np.percentile(ttfts, 50)) * 1e3,
                ttft_p95_ms=float(np.percentile(ttfts, 95)) * 1e3,
            ),
        )


def part5_faults(cfg0, params, bundle):
    print("\n== fault plane: scripted link flap -> degraded tokens + "
          "availability re-solve ==")
    cfg = dataclasses.replace(
        cfg0, exit_threshold=_mixed_threshold(cfg0, params)
    )
    slots = 4
    n_req = 8 if FAST else 24
    tiers = [
        TierSpec("edge", 4.0, 1e9),
        TierSpec("mid", 2.0, 1e9),
        TierSpec("cloud", 1.0),
    ]
    fault_model = LinkFaultModel(
        seed=0, flaps=(FlapWindow(hop=1, start_step=6, end_step=10_000),)
    )
    policy = HopPolicy(
        timeout_s=0.02, max_retries=1, backoff_s=0.002,
        breaker_threshold=2, breaker_cooldown_steps=3,
    )
    srv = MultiTierServer(
        cfg, params, tiers, (1, 3), simulate_network=True,
        slots=slots, context_len=CONTEXT,
        fault_model=fault_model, hop_policy=policy,
    )
    costs = [LayerCost(f"l{i}", 0, 0, cfg.d_model * 2.0, 1e-3)
             for i in range(cfg.num_layers)]
    profile = build_cost_profile(
        costs, cfg.branch_layers, np.array([0.2, 0.2]), "3g", 50.0, 64.0
    )
    ctl = RepartitionController(srv, profile, tiers=list(tiers))
    work = _request_workload(cfg, n_req, seed=3)

    sched = RequestScheduler(srv, slots, CONTEXT, on_step=[ctl.observe])
    t0 = time.perf_counter()
    for w in work:
        sched.submit(w["prompt"], w["max_new_tokens"],
                     stop_on_exit=w["stop_on_exit"],
                     arrival_step=w["arrival_step"])
    results = sched.drain()
    dt = time.perf_counter() - t0

    tokens = sched.total_tokens
    degraded_tokens = sum(r.degraded_tokens for r in results)
    deg_frac = degraded_tokens / max(tokens, 1)
    ex = srv.executor
    print(f"requests {len(results)}  tokens {tokens}  "
          f"tok/s {tokens / dt:.1f}")
    print(f"degraded tokens {degraded_tokens} ({deg_frac:.1%})  "
          f"degraded steps {ex.degraded_steps}  retries {ex.fault_retries}")
    print(f"fault re-solves {ctl.fault_resolves}  cuts now {srv.cuts}  "
          f"hop health {ctl.hop_health()}")
    assert len(results) == n_req and all(r.done for r in results), \
        "every request must complete despite the dead link"
    assert {r.status for r in results} <= {"ok", "degraded"}
    assert degraded_tokens > 0, "the flap must force degraded tokens"
    assert ctl.fault_resolves >= 1, "breaker-open must trigger a re-solve"
    assert srv.cuts[1] == cfg.num_layers, \
        "the re-solved plan must ship nothing on the sick hop"
    assert sched.active.sum() == 0 and all(
        r is None for r in sched._slot_req
    ), "no leaked KV slots"
    print(f"OK: {n_req} requests survived a hop-1 kill — {deg_frac:.1%} of "
          f"tokens finalized from the fallback head, "
          f"{ctl.fault_resolves} availability re-solve(s)")
    bundle.cell(
        "faults",
        config=dict(slots=slots, requests=n_req, flap_hop=1,
                    flap_start=6, fast=FAST),
        strict=dict(
            requests_done=len(results),
            failed_requests=sum(r.status == "failed" for r in results),
            fault_resolves=ctl.fault_resolves,
            sick_hop_bytes_after_resolve=0.0,
        ),
        timing=dict(
            tokens_per_s=tokens / dt,
            degraded_token_frac=deg_frac,
            degraded_steps=ex.degraded_steps,
            fault_retries=ex.fault_retries,
        ),
    )


def main() -> None:
    cfg = dataclasses.replace(
        get_smoke_config("qwen3_8b"), num_layers=4, branch_layers=(1, 3)
    )
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    print(f"{cfg.name} (reduced): {cfg.num_layers} layers, "
          f"branches {cfg.branch_layers}, batch {BATCH}"
          f"{' [fast mode]' if FAST else ''}")

    bundle = BenchBundle("serving")
    try:
        if ONLY == "overlap":
            part3_overlap_pipeline(cfg, params, bundle)
            return
        if ONLY == "requests":
            part4_continuous_batching(cfg, params, bundle)
            return
        if ONLY == "faults":
            part5_faults(cfg, params, bundle)
            return
        part1_legacy_vs_fused(cfg, params, bundle)
        part2_roofline_sweep(cfg, params, bundle)
        part3_overlap_pipeline(cfg, params, bundle)
        part4_continuous_batching(cfg, params, bundle)
        part5_faults(cfg, params, bundle)
    finally:
        print(f"\nwrote {bundle.write()}")


if __name__ == "__main__":
    main()
