"""B-AlexNet per-layer cost profile — shared input for the Fig. 4/5/6
reproductions.

The paper measures t_i^c on Google Colab (K80); we measure the same chain
on the local device (and cache it as JSON so the figure benchmarks are
deterministic and fast).  alpha_i is the per-layer output size — the exact
quantity that crosses the edge->cloud uplink.
"""

from __future__ import annotations

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import LayerCost, measure_layer_times
from repro.models.alexnet import BAlexNetConfig, init_b_alexnet, layer_fns

CACHE = Path(__file__).resolve().parent.parent / "results" / "alexnet_profile.json"

#: Raw 224x224x3 fp32 image — the paper's alpha_0 (cloud-only upload).
RAW_INPUT_BYTES = 224 * 224 * 3 * 4


def profile(batch: int = 1, force: bool = False) -> list[LayerCost]:
    if CACHE.exists() and not force:
        data = json.loads(CACHE.read_text())
        return [LayerCost(**row) for row in data]
    params = init_b_alexnet(jax.random.PRNGKey(0))
    fns = layer_fns(params)
    # Chain the abstract inputs through the layers.
    x = jnp.zeros((batch, 224, 224, 3), jnp.float32)
    inputs = []
    for name, fn in fns:
        inputs.append(x)
        x = jax.eval_shape(fn, x)
        x = jnp.zeros(x.shape, x.dtype)
    costs = measure_layer_times(fns, inputs, iters=20, warmup=3)
    CACHE.parent.mkdir(parents=True, exist_ok=True)
    CACHE.write_text(json.dumps([c.__dict__ for c in costs]))
    return costs
