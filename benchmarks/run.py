"""Benchmark harness — one function per paper table/figure plus the
framework-level tables.  Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run fig4 fig6  # subset
"""

from __future__ import annotations

import sys
import traceback


def bench_fig4() -> list[str]:
    from benchmarks.fig4_inference_time import run

    return run()


def bench_fig5() -> list[str]:
    from benchmarks.fig5_partition_layer import run

    return run()


def bench_fig6() -> list[str]:
    from benchmarks.fig6_calibration import run

    return run()


def bench_solver() -> list[str]:
    """Partitioner solver throughput: Dijkstra vs closed-form vs vmapped."""
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import (
        BranchSpec,
        CostProfile,
        NetworkProfile,
        brute_force_split,
        shortest_path_plan,
        solve_chain_jax,
    )

    rng = np.random.default_rng(0)
    n = 64  # a deep chain (e.g. an 80-layer trunk with branches)
    t_c = np.concatenate([[0.0], rng.uniform(1e-3, 1e-1, n)])
    alpha = rng.uniform(1e3, 1e6, n + 1)
    branches = tuple(BranchSpec(i, 0.3) for i in (8, 16, 32, 48))
    prof = CostProfile(
        t_c=t_c, alpha=alpha, branches=branches, gamma=100.0,
        network=NetworkProfile("bench", 5.85e6),
    )
    iters = 200
    t0 = time.perf_counter()
    for _ in range(iters):
        plan = shortest_path_plan(prof)
    dt_dij = (time.perf_counter() - t0) / iters * 1e6
    t0 = time.perf_counter()
    for _ in range(iters):
        brute_force_split(prof)
    dt_bf = (time.perf_counter() - t0) / iters * 1e6

    # vmapped solve over a 1000-point bandwidth grid.
    p = np.zeros(n + 1)
    for b in branches:
        p[b.after_layer] = b.exit_prob
    bws = jnp.logspace(5, 9, 1000)
    f = jax.jit(
        jax.vmap(
            lambda bw: solve_chain_jax(
                jnp.asarray(t_c), jnp.asarray(alpha), jnp.asarray(p),
                jnp.asarray(100.0), bw,
            )[1]
        )
    )
    jax.block_until_ready(f(bws))
    t0 = time.perf_counter()
    for _ in range(50):
        jax.block_until_ready(f(bws))
    dt_vmap = (time.perf_counter() - t0) / 50 / 1000 * 1e6

    return [
        f"solver/dijkstra_n64,{dt_dij:.1f},split={plan.split_layer}",
        f"solver/closed_form_n64,{dt_bf:.1f},oracle",
        f"solver/vmap_per_point_n64,{dt_vmap:.3f},grid=1000",
    ]


def bench_kernels() -> list[str]:
    from benchmarks.kernel_micro import run

    return run()


def bench_roofline() -> list[str]:
    from benchmarks.roofline import csv_rows

    rows = csv_rows()
    return rows or ["roofline/no_dryrun_results,0.0,run repro.launch.dryrun first"]


def bench_partitioned_serving() -> list[str]:
    """End-to-end partitioned decode on a smoke model: bytes shipped and
    expected latency per split (the paper's system, measured)."""
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.core import BranchSpec, CostProfile, NetworkProfile
    from repro.models import model as M
    from repro.serving.partitioned import PartitionedServer

    cfg = get_smoke_config("phi3_mini_3_8b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    n = cfg.num_layers
    prof = CostProfile(
        t_c=np.concatenate([[0.0], np.full(n, 1e-3)]),
        alpha=np.concatenate([[224 * 224 * 3 * 4.0], np.full(n, cfg.d_model * 2.0)]),
        branches=tuple(BranchSpec(b, 0.5) for b in cfg.branch_layers),
        gamma=10.0,
        network=NetworkProfile("4g", 5.85e6),
    )
    rows = []
    for split in (0, 1, n):
        srv = PartitionedServer(cfg, params, split, cost_profile=prof)
        caches = M.init_caches(cfg, 8, 64)
        tok = jnp.zeros((8, 1), jnp.int32)
        rep, caches = srv.step(tok, 0, caches)  # warm
        t0 = time.perf_counter()
        for i in range(5):
            rep, caches = srv.step(tok, i + 1, caches)
        dt = (time.perf_counter() - t0) / 5 * 1e6
        est = "-" if rep.est_latency_s is None else f"{rep.est_latency_s:.5f}"
        rows.append(
            f"serving/partitioned_split{split},{dt:.0f},"
            f"shipped={rep.shipped}/8;bytes={rep.bytes_shipped:.0f};estT={est}"
        )
    return rows


BENCHES = {
    "fig4": bench_fig4,
    "fig5": bench_fig5,
    "fig6": bench_fig6,
    "solver": bench_solver,
    "kernels": bench_kernels,
    "roofline": bench_roofline,
    "serving": bench_partitioned_serving,
}


def main() -> None:
    names = [a for a in sys.argv[1:] if a in BENCHES] or list(BENCHES)
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        try:
            for row in BENCHES[name]():
                print(row)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name}/FAILED,0.0,{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
