"""Paper Fig. 5: chosen partition layer vs edge slowdown gamma, per exit
probability, for 3G and 4G.

Claims checked: as gamma grows the split moves toward the input (cloud-only
= split 0); higher bandwidth (4G) flips to cloud-only at LOWER gamma than
3G; higher p keeps layers on the edge longer.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.alexnet_profile import RAW_INPUT_BYTES, profile
from repro.core import UPLINK_PRESETS
from repro.core.shortest_path import solve_chain_jax

PROBS = (0.0, 0.2, 0.5, 0.8)
BRANCH_AFTER = 1


def sweep(n_gamma: int = 60):
    costs = profile()
    t_c = jnp.asarray([0.0] + [c.time_s for c in costs])
    alpha = jnp.asarray([RAW_INPUT_BYTES] + [c.output_bytes for c in costs])
    n = len(costs)
    gammas = jnp.logspace(0, 3, n_gamma)

    def solve(gamma, p, bw):
        pvec = jnp.zeros(n + 1).at[BRANCH_AFTER].set(p)
        s, t = solve_chain_jax(t_c, alpha, pvec, gamma, bw)
        return s

    solve_v = jax.jit(jax.vmap(solve, in_axes=(0, None, None)))
    out = {}
    for net in ("3g", "4g"):
        bw = UPLINK_PRESETS[net].bandwidth_bps
        for p in PROBS:
            out[(net, p)] = (
                np.asarray(gammas),
                np.asarray(solve_v(gammas, jnp.asarray(p), jnp.asarray(bw))),
            )
    return out


def validate(results) -> dict:
    rep = {}
    for (net, p), (g, s) in results.items():
        # Partition layer moves toward the input as gamma grows (weakly).
        rep[f"monotone_{net}_p{p}"] = bool(np.all(np.diff(s) <= 0))
    # 4G flips to cloud-only no later than 3G (higher bw favors cloud).
    for p in PROBS:
        g3, s3 = results[("3g", p)]
        g4, s4 = results[("4g", p)]
        flip3 = g3[np.argmax(s3 == 0)] if (s3 == 0).any() else np.inf
        flip4 = g4[np.argmax(s4 == 0)] if (s4 == 0).any() else np.inf
        rep[f"4g_flips_first_p{p}"] = bool(flip4 <= flip3)
    return rep


def run() -> list[str]:
    t0 = time.perf_counter()
    results = sweep()
    dt = (time.perf_counter() - t0) * 1e6
    rep = validate(results)
    rows = [f"fig5/sweep,{dt / max(len(results), 1):.2f},curves={len(results)}"]
    ok_mono = all(v for k, v in rep.items() if k.startswith("monotone"))
    ok_flip = all(v for k, v in rep.items() if k.startswith("4g_flips"))
    # Example trace: split at gamma extremes for 3G, p=0.8 (paper's example).
    g, s = results[("3g", 0.8)]
    rows.append(
        f"fig5/claims,0.0,monotone={ok_mono};4g_flips_first={ok_flip};"
        f"split_at_gamma1={int(s[0])};split_at_gamma1000={int(s[-1])}"
    )
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
