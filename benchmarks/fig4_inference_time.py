"""Paper Fig. 4: E[T_inf] vs side-branch exit probability, for 3G/4G/Wi-Fi
uplinks and edge slowdown factors gamma in {10, 100, 1000}.

Reproduces the paper's qualitative claims and quantifies ours:

  * inference time is monotone non-increasing in p;
  * at p == 1 all three networks coincide (nothing is ever shipped);
  * lower-bandwidth uplinks benefit more from p (the paper reports
    reductions of 87.27% / 82.98% / 70% for 3G / 4G / Wi-Fi at gamma=10 —
    the exact values depend on their K80 layer times, ours are measured on
    this host, but the ORDERING 3G > 4G > Wi-Fi is hardware-independent);
  * the whole figure is ONE vmapped shortest-path solve (beyond-paper:
    the paper runs Dijkstra per point).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.alexnet_profile import RAW_INPUT_BYTES, profile
from repro.core import UPLINK_PRESETS, chain_costs_jax
from repro.core.shortest_path import solve_chain_jax

GAMMAS = (10.0, 100.0, 1000.0)
NETWORKS = ("3g", "4g", "wifi")
BRANCH_AFTER = 1  # the paper's single branch after conv1


def _arrays():
    costs = profile()
    t_c = jnp.asarray([0.0] + [c.time_s for c in costs])
    alpha = jnp.asarray([RAW_INPUT_BYTES] + [c.output_bytes for c in costs])
    return t_c, alpha, len(costs)


def sweep(n_points: int = 101):
    """Returns {(net, gamma): (ps, expected_times, splits)}."""
    t_c, alpha, n = _arrays()
    ps = jnp.linspace(0.0, 1.0, n_points)

    def solve(p, gamma, bw):
        pvec = jnp.zeros(n + 1).at[BRANCH_AFTER].set(p)
        s, t = solve_chain_jax(t_c, alpha, pvec, gamma, bw)
        return s, t

    solve_v = jax.jit(jax.vmap(solve, in_axes=(0, None, None)))
    out = {}
    for net in NETWORKS:
        bw = UPLINK_PRESETS[net].bandwidth_bps
        for g in GAMMAS:
            s, t = solve_v(ps, jnp.asarray(g), jnp.asarray(bw))
            out[(net, g)] = (np.asarray(ps), np.asarray(t), np.asarray(s))
    return out


def validate(results) -> dict:
    """The paper's claims, checked numerically."""
    report = {}
    for g in GAMMAS:
        t_at_1 = [results[(net, g)][1][-1] for net in NETWORKS]
        report[f"p1_equal_gamma{int(g)}"] = bool(
            np.allclose(t_at_1, t_at_1[0], rtol=1e-6)
        )
        reductions = {}
        for net in NETWORKS:
            t = results[(net, g)][1]
            report[f"monotone_{net}_gamma{int(g)}"] = bool(
                np.all(np.diff(t) <= 1e-12)
            )
            reductions[net] = float((t[0] - t[-1]) / t[0] * 100.0)
        report[f"reduction_pct_gamma{int(g)}"] = reductions
        report[f"ordering_3g>=4g>=wifi_gamma{int(g)}"] = bool(
            reductions["3g"] >= reductions["4g"] >= reductions["wifi"] - 1e-9
        )
    return report


def run() -> list[str]:
    t0 = time.perf_counter()
    results = sweep()
    dt = (time.perf_counter() - t0) * 1e6
    report = validate(results)
    rows = []
    n_pts = sum(len(v[0]) for v in results.values())
    rows.append(f"fig4/full_sweep,{dt / max(n_pts, 1):.2f},points={n_pts}")
    for g in GAMMAS:
        red = report[f"reduction_pct_gamma{int(g)}"]
        rows.append(
            f"fig4/reduction_gamma{int(g)},0.0,"
            f"3g={red['3g']:.2f}%;4g={red['4g']:.2f}%;wifi={red['wifi']:.2f}%;"
            f"p1_equal={report[f'p1_equal_gamma{int(g)}']};"
            f"ordering={report[f'ordering_3g>=4g>=wifi_gamma{int(g)}']}"
        )
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
