"""Paper Fig. 6: P[classified at side branch] vs entropy threshold under
three Gaussian-blur distortion levels (kernel sizes 5 / 15 / 65, as in the
paper), on B-AlexNet.

The paper trains on a cat-vs-dog dataset; offline here, we train on a
synthetic two-class image task (class-dependent oriented textures) — the
figure's *claim* is dataset-independent: heavier blur -> flatter branch
posterior -> higher entropy -> lower exit probability at any threshold.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import threshold_sweep
from repro.core.calibration import normalized_entropy
from repro.models.alexnet import forward, init_b_alexnet

KERNELS = {"low": 5, "mid": 15, "high": 65}
THRESHOLDS = np.linspace(0.05, 1.0, 20)


def make_images(key, n: int, size: int = 224):
    """Two-class oriented-texture 'animals'."""
    k1, k2, k3 = jax.random.split(key, 3)
    labels = jax.random.bernoulli(k1, 0.5, (n,)).astype(jnp.int32)
    xs = jnp.linspace(0, 8 * np.pi, size)
    horiz = jnp.sin(xs)[None, :, None]  # varies along width
    vert = jnp.sin(xs)[:, None, None]  # varies along height
    phase = jax.random.uniform(k3, (n, 1, 1, 1)) * 2 * np.pi
    base = jnp.where(
        labels[:, None, None, None] == 0,
        jnp.sin(xs[None, None, :, None] + phase),
        jnp.sin(xs[None, :, None, None] + phase),
    )
    img = jnp.broadcast_to(base, (n, size, size, 1))
    img = jnp.concatenate([img] * 3, axis=-1)
    noise = jax.random.normal(k2, img.shape) * 0.3
    return (img + noise).astype(jnp.float32), labels


def gaussian_blur(img, ksize: int):
    """Separable Gaussian blur, sigma = ksize/6 (matches paper's kernels)."""
    sigma = max(ksize / 6.0, 1e-3)
    xs = jnp.arange(ksize, dtype=jnp.float32) - (ksize - 1) / 2
    kern = jnp.exp(-0.5 * (xs / sigma) ** 2)
    kern = kern / kern.sum()

    # Separable blur: shifted-add along H then W (edge padding).
    def blur_axis(x, axis):
        pad = [(0, 0)] * x.ndim
        half = ksize // 2
        pad[axis] = (half, ksize - 1 - half)
        xp = jnp.pad(x, pad, mode="edge")
        idx = [slice(None)] * x.ndim
        out = jnp.zeros_like(x)
        for i in range(ksize):
            idx[axis] = slice(i, i + x.shape[axis])
            out = out + kern[i] * xp[tuple(idx)]
        return out

    return blur_axis(blur_axis(img, 1), 2)


def train_b_alexnet(key, steps: int = 30, batch: int = 16, lr: float = 3e-4):
    params = init_b_alexnet(key)

    def loss_fn(p, img, lab):
        main, branch = forward(p, img)
        onehot = jax.nn.one_hot(lab, 2)
        lm = -jnp.mean(jnp.sum(jax.nn.log_softmax(main) * onehot, -1))
        lb = -jnp.mean(jnp.sum(jax.nn.log_softmax(branch) * onehot, -1))
        return lm + 0.5 * lb

    @jax.jit
    def step(p, img, lab):
        l, g = jax.value_and_grad(loss_fn)(p, img, lab)
        p = jax.tree_util.tree_map(lambda w, gw: w - lr * gw, p, g)
        return p, l

    for i in range(steps):
        img, lab = make_images(jax.random.fold_in(key, i), batch)
        params, l = step(params, img, lab)
    return params, float(l)


def run(n_eval: int = 48) -> list[str]:
    """n_eval=48 matches the paper's 48-sample batch."""
    t0 = time.perf_counter()
    key = jax.random.PRNGKey(7)
    params, final_loss = train_b_alexnet(key)
    img, lab = make_images(jax.random.fold_in(key, 999), n_eval)

    fwd = jax.jit(lambda p, x: forward(p, x))
    curves = {}
    accs = {}
    for name, ksize in KERNELS.items():
        blurred = gaussian_blur(img, ksize)
        main, branch = fwd(params, blurred)
        ents = np.asarray(normalized_entropy(branch))[None, :]  # (K=1, B)
        curves[name] = threshold_sweep(ents, THRESHOLDS)[:, 0]
        accs[name] = float((np.argmax(np.asarray(main), -1) == np.asarray(lab)).mean())
    dt = (time.perf_counter() - t0) * 1e6

    # Claim: at every threshold, heavier distortion -> lower exit probability
    # (checked in aggregate: mean over thresholds strictly ordered).
    m_low, m_mid, m_high = (curves[k].mean() for k in ("low", "mid", "high"))
    ordered = bool(m_low >= m_mid >= m_high)
    mono = all(bool(np.all(np.diff(c) >= -1e-12)) for c in curves.values())
    rows = [
        f"fig6/train+sweep,{dt:.0f},loss={final_loss:.3f};acc_low={accs['low']:.2f}",
        (
            f"fig6/claims,0.0,exit_prob_low>=mid>=high={ordered};"
            f"monotone_in_threshold={mono};"
            f"mean_exit_low={m_low:.3f};mid={m_mid:.3f};high={m_high:.3f}"
        ),
    ]
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
