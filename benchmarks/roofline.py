"""Roofline analysis from the dry-run's compiled artifacts (deliverable g).

Three terms per (arch x shape) on the single-pod mesh, seconds per step:

    compute    = HLO_FLOPs_per_chip / peak_FLOPs        (197 TFLOP/s bf16)
    memory     = HLO_bytes_per_chip / HBM_bw            (819 GB/s)
    collective = collective_bytes_per_chip / link_bw    (~50 GB/s/link ICI)

``cost_analysis()`` on the SPMD-partitioned module is already per-chip;
collective bytes come from parsing the optimized HLO (launch/dryrun.py).

MODEL_FLOPS uses 6*N*D for training (fwd+bwd) and 2*N*D for inference
steps, with N = active params (MoE: top-k + shared) and D = tokens
processed per step.  The ratio MODEL_FLOPS / HLO_FLOPs exposes
remat/dispatch/attention overhead (attention FLOPs are extra real work, so
the ratio is a *lower bound* on usefulness).
"""

from __future__ import annotations

import json
from pathlib import Path

from repro.configs import INPUT_SHAPES, get_config

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
CHIPS = {"pod16x16": 256, "pod2x16x16": 512}

RESULTS = Path(__file__).resolve().parent.parent / "results" / "dryrun"


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    n = cfg.active_params()
    tokens = shape.global_batch * (1 if shape.is_decode else shape.seq_len)
    mult = 6 if shape.kind == "train" else 2
    return mult * n * tokens


def load_records(mesh: str = "pod16x16", tag: str = "") -> list[dict]:
    out = []
    suffix = f"__{tag}" if tag else ""
    for p in sorted(RESULTS.glob(f"*__{mesh}{suffix}.json")):
        rec = json.loads(p.read_text())
        if tag == "" and rec.get("tag"):
            continue
        out.append(rec)
    return out


def analyze(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    chips = CHIPS[rec["mesh"]]
    cost = rec["cost"]
    colls = rec["collectives"]
    coll_bytes = sum(v for k, v in colls.items() if k != "_counts")
    # Prefer the trip-count-corrected totals (launch/hlo_analysis.py);
    # XLA cost_analysis counts while-loop bodies once and is kept only as
    # a fallback for records produced before the correction.
    flops = rec.get("dot_flops") or cost["flops"]
    bytes_acc = rec.get("hbm_bytes") or cost["bytes_accessed"]
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_coll = coll_bytes / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"]) / chips
    ratio = mf / flops if flops else 0.0
    bound = max(terms.values())
    mfu_bound = (mf / PEAK_FLOPS) / bound if bound else 0.0
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_chip": mf,
        "hlo_flops_per_chip": flops,
        "uncorrected_cost_flops": cost["flops"],
        "useful_ratio": ratio,
        "mfu_upper_bound": mfu_bound,
        "peak_gb_per_dev": rec["memory"]["peak_bytes_est"] / 1e9,
    }


_SUGGESTIONS = {
    "compute": "reduce redundant FLOPs (dispatch einsums, causal-block "
    "skipping in flash attention, remat policy)",
    "memory": "raise arithmetic intensity (fuse norms/rope, bigger per-chip "
    "batch, bf16 residuals, windowed cache)",
    "collective": "reshard to cut gathers (kv-head vs head-dim sharding, "
    "FSDP prefetch, overlap collectives with compute)",
}


def table(mesh: str = "pod16x16", tag: str = "") -> str:
    rows = [analyze(r) for r in load_records(mesh, tag)]
    rows = [r for r in rows if r]
    hdr = (
        f"{'arch':22s} {'shape':12s} {'compute':>10s} {'memory':>10s} "
        f"{'collect':>10s} {'dom':>9s} {'useful':>7s} {'MFU<=':>6s} {'GB/dev':>7s}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} "
            f"{r['t_compute_s']:10.4f} {r['t_memory_s']:10.4f} "
            f"{r['t_collective_s']:10.4f} {r['dominant']:>9s} "
            f"{r['useful_ratio']:7.3f} {r['mfu_upper_bound']:6.2f} "
            f"{r['peak_gb_per_dev']:7.2f}"
        )
    return "\n".join(lines)


def csv_rows(mesh: str = "pod16x16") -> list[str]:
    """benchmarks/run.py contract: name,us_per_call,derived."""
    out = []
    for r in load_records(mesh):
        a = analyze(r)
        if not a:
            continue
        step_s = max(a["t_compute_s"], a["t_memory_s"], a["t_collective_s"])
        out.append(
            f"roofline/{a['arch']}/{a['shape']},{step_s * 1e6:.1f},"
            f"dom={a['dominant']};useful={a['useful_ratio']:.3f};"
            f"gb={a['peak_gb_per_dev']:.2f}"
        )
    return out


def main() -> None:
    print(table())


if __name__ == "__main__":
    main()
