"""Kernel microbenchmarks: kernel-vs-jnp decode hot path sweep.

Two parts:

Part 1 (reference timings): wall-clock of the pure-jnp reference paths at
serving-scale shapes — what a CPU host actually executes, and the
baseline the Pallas kernels must beat on TPU.

Part 2 (kernel-vs-jnp decode-step sweep): for each decode hot spot the
``use_kernels`` plumbing swaps, time BOTH paths across batch x bucket
(survivor sub-batch width) x cache length, plus the end-to-end
``TierExecutor`` decode step with kernels on/off:

  * flash_decode: Pallas survivor-row streaming vs jnp gather +
    flash_attention (the attn_apply decode branch);
  * entropy_exit_argmax: the fused exit decision vs inline
    normalized_entropy + argmax (the TierExecutor branch masking);
  * ssd_update: the Pallas SSD step vs models.mamba.ssd_step;
  * tier_step: a full K=2 bucketed TierExecutor decode step.

On CPU the kernels run in *interpret mode*, so their absolute numbers are
meaningless (orders of magnitude slow) — the sweep's value off-TPU is (a)
CI proof that every kernel path executes end to end at serving shapes and
(b) the harness the profiler/cost layer will point at a real TPU to get
kernel-true ``compute_j`` timings for the lattice solver.  The jnp column
is the honest CPU cost either way.

Output rows: ``name,shape,us_kernel,us_jnp`` (Part 2) appended after the
Part 1 ``name,us,impl`` rows.

Run:  PYTHONPATH=src python benchmarks/kernel_micro.py
Fast CI smoke:  REPRO_BENCH_FAST=1 PYTHONPATH=src python benchmarks/kernel_micro.py
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from bench_io import BenchBundle
from repro.kernels import ops, ref

FAST = bool(int(os.environ.get("REPRO_BENCH_FAST", "0")))


def _time(fn, *args, iters=20, warmup=3) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run_reference() -> list[str]:
    """Part 1: jnp reference paths at serving-scale shapes."""
    rows = []
    key = jax.random.PRNGKey(0)

    # Entropy-exit over a Qwen3-sized vocab (the per-branch confidence test).
    logits = jax.random.normal(key, (64, 151_936), jnp.float32)
    f = jax.jit(lambda x: ref.entropy_exit_ref(x, 0.5))
    rows.append(f"kernel/entropy_exit_b64_v152k,{_time(f, logits):.1f},jnp_ref")

    # Flash-decode against a 32k cache (decode_32k per-layer shape).
    q = jax.random.normal(key, (8, 32, 128), jnp.bfloat16)
    k = jax.random.normal(key, (8, 32_768, 8, 128), jnp.bfloat16)
    v = jax.random.normal(key, (8, 32_768, 8, 128), jnp.bfloat16)
    pos = jnp.arange(32_768, dtype=jnp.int32)
    qpos = jnp.asarray(32_768, jnp.int32)
    f = jax.jit(lambda *a: ref.flash_decode_ref(*a))
    rows.append(
        f"kernel/flash_decode_b8_c32k,{_time(f, q, k, v, pos, qpos):.1f},jnp_ref"
    )

    # SSD scan, mamba2-130m block shape, 4k tokens.
    from repro.models.mamba import ssd_chunked

    x = jax.random.normal(key, (2, 4096, 24, 64), jnp.float32) * 0.5
    a = -jnp.abs(jax.random.normal(key, (2, 4096, 24))) * 0.3
    bm = jax.random.normal(key, (2, 4096, 24, 128)) * 0.5
    cm = jax.random.normal(key, (2, 4096, 24, 128)) * 0.5
    f = jax.jit(lambda *args: ssd_chunked(*args, chunk=64))
    rows.append(f"kernel/ssd_chunked_4k,{_time(f, x, a, bm, cm, iters=5):.1f},jnp_chunked")

    return rows


# ------------------------------------------------------- part 2: the sweep
ITERS = 2 if FAST else 10
WARMUP = 1 if FAST else 3
# (full batch resident in the cache, survivor bucket, cache slots)
DECODE_CELLS = (
    [(8, 4, 256)] if FAST
    else [(8, 4, 256), (8, 8, 1024), (16, 4, 1024), (16, 16, 4096)]
)


def _pair(name: str, shape: str, t_kernel: float, t_jnp: float) -> str:
    return f"{name},{shape},{t_kernel:.1f},{t_jnp:.1f}"


def sweep_flash_decode() -> list[str]:
    rows = []
    kh, g, d = 2, 4, 64
    for batch, bucket, cache in DECODE_CELLS:
        ks = jax.random.split(jax.random.PRNGKey(cache + bucket), 3)
        q = jax.random.normal(ks[0], (bucket, kh * g, d), jnp.float32)
        k = jax.random.normal(ks[1], (batch, cache, kh, d), jnp.float32)
        v = jax.random.normal(ks[2], (batch, cache, kh, d), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(cache, dtype=jnp.int32), (batch, cache))
        qpos = jnp.asarray(cache, jnp.int32)
        rows_map = jnp.arange(bucket, dtype=jnp.int32)  # survivors-first order

        t_k = _time(
            lambda: ops.flash_decode(q, k, v, pos, qpos, rows_map),
            iters=ITERS, warmup=WARMUP,
        )
        jf = jax.jit(
            lambda q, k, v, pos, qpos, r: ref.flash_decode_ref(
                q, k, v, pos, qpos, r
            )
        )
        t_j = _time(lambda: jf(q, k, v, pos, qpos, rows_map),
                    iters=ITERS, warmup=WARMUP)
        rows.append(_pair(
            "sweep/flash_decode", f"b{batch}_rows{bucket}_c{cache}", t_k, t_j
        ))
    return rows


def sweep_entropy_exit() -> list[str]:
    rows = []
    from repro.core.calibration import normalized_entropy

    vocab = 2048 if FAST else 32_064
    for batch, bucket, _ in DECODE_CELLS:
        logits = jax.random.normal(
            jax.random.PRNGKey(bucket), (bucket, vocab), jnp.float32
        ) * 4
        t_k = _time(lambda: ops.entropy_exit_argmax(logits, 0.5),
                    iters=ITERS, warmup=WARMUP)
        jf = jax.jit(lambda l: (
            normalized_entropy(l),
            normalized_entropy(l) < 0.5,
            jnp.argmax(l, -1).astype(jnp.int32),
        ))
        t_j = _time(lambda: jf(logits), iters=ITERS, warmup=WARMUP)
        rows.append(_pair(
            "sweep/entropy_exit_argmax", f"rows{bucket}_v{vocab}", t_k, t_j
        ))
    return rows


def sweep_ssd_update() -> list[str]:
    rows = []
    from repro.models.mamba import ssd_step

    h, p, n, g = (4, 64, 32, 1) if FAST else (24, 64, 128, 1)
    for batch, bucket, _ in DECODE_CELLS:
        ks = jax.random.split(jax.random.PRNGKey(batch * bucket), 5)
        hs = jax.random.normal(ks[0], (batch, h, p, n), jnp.float32)
        x = jax.random.normal(ks[1], (bucket, h, p)) * 0.5
        a = -jnp.abs(jax.random.normal(ks[2], (bucket, h))) * 0.3
        bv = jax.random.normal(ks[3], (bucket, g, n)) * 0.5
        cv = jax.random.normal(ks[4], (bucket, g, n)) * 0.5
        rows_map = jnp.arange(bucket, dtype=jnp.int32)
        t_k = _time(lambda: ops.ssd_update(hs, x, a, bv, cv, rows_map),
                    iters=ITERS, warmup=WARMUP)
        jf = jax.jit(lambda hs, x, a, bv, cv, r: ssd_step(hs[r], x, a, bv, cv))
        t_j = _time(lambda: jf(hs, x, a, bv, cv, rows_map),
                    iters=ITERS, warmup=WARMUP)
        rows.append(_pair(
            "sweep/ssd_update", f"b{batch}_rows{bucket}", t_k, t_j
        ))
    return rows


def sweep_entropy_heads() -> list[str]:
    """Multi-head fused exit decision: ONE (K, B, V) kernel launch vs K
    single-head launches over the same stacked logits (per-head outputs
    are bitwise identical by construction — asserted here)."""
    rows = []
    vocab = 2048 if FAST else 32_064
    ks = (3,) if FAST else (2, 3, 5)
    for k in ks:
        for batch, bucket, _ in DECODE_CELLS[:1]:
            logits = jax.random.normal(
                jax.random.PRNGKey(k * 7 + bucket), (k, bucket, vocab),
                jnp.float32
            ) * 4
            th = jnp.linspace(0.3, 0.7, k)
            multi = jax.jit(lambda l: ops.entropy_exit_argmax_heads(l, th))
            single = jax.jit(lambda l: [
                ops.entropy_exit_argmax(l[j], th[j]) for j in range(k)
            ])
            e, f, t = multi(logits)
            for j, (ej, fj, tj) in enumerate(single(logits)):
                np.testing.assert_array_equal(np.asarray(e[j]), np.asarray(ej))
                np.testing.assert_array_equal(np.asarray(f[j]), np.asarray(fj))
                np.testing.assert_array_equal(np.asarray(t[j]), np.asarray(tj))
            t_multi = _time(lambda: multi(logits), iters=ITERS, warmup=WARMUP)
            t_single = _time(lambda: single(logits), iters=ITERS, warmup=WARMUP)
            rows.append(_pair(
                "heads/entropy_exit_argmax_heads",
                f"k{k}_rows{bucket}_v{vocab}", t_multi, t_single,
            ))
    return rows


def sweep_heads_batched() -> list[str]:
    """End-to-end probe-step (all-heads) TierExecutor decode: batched exit
    heads (one stacked projection + one multi-head exit decision) vs the
    sequential per-head path.  Shapes are chosen so the K=5 exit heads
    carry the head-bandwidth term the batching amortizes (d_model 1024,
    16k vocab: each sequential head re-streams the unembedding).  The
    trajectories and exit masks must be bitwise identical; the full run
    asserts the >=1.2x probe-step speedup the batching is for (FAST keeps
    a loose >=1.0 sanity floor — 2 timed steps are too noisy to gate on).
    """
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.models import model as M
    from repro.serving import TierExecutor, segments_for_cuts

    cfg = dataclasses.replace(
        get_smoke_config("phi3_mini_3_8b"), num_layers=6,
        branch_layers=(1, 2, 3, 4, 5), d_model=1024, vocab_size=16_384,
    )
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = 8
    steps = 2 if FAST else 8
    times = {}
    trajs = {}
    masks = {}
    for batched in (True, False):
        ex = TierExecutor(
            cfg, params, segments_for_cuts(cfg, (5,)),
            batched_heads=batched,
        )
        caches = M.init_caches(cfg, batch, 64)
        tok = jax.random.randint(
            jax.random.PRNGKey(2), (batch, 1), 0, cfg.vocab_size
        )
        ex.probe_next = True
        res, caches = ex.step(tok, 0, caches)  # compile + warm hints
        ex.probe_next = True  # warm the probe-step compile as well
        res, caches = ex.step(res.tokens_dev[:, None], 1, caches)
        t0 = time.perf_counter()
        traj, msk = [], []
        for i in range(steps):
            ex.probe_next = True  # every timed step evaluates all K heads
            res, caches = ex.step(res.tokens_dev[:, None], i + 2, caches)
            traj.append(res.tokens)
            msk.append(res.exited)
        times[batched] = (time.perf_counter() - t0) / steps * 1e6
        trajs[batched], masks[batched] = traj, msk
        assert ex.host_syncs == steps + 2 + ex.overflow_retries
    for a, b in zip(trajs[True], trajs[False]):
        np.testing.assert_array_equal(a, b)  # identical trajectory
    for a, b in zip(masks[True], masks[False]):
        np.testing.assert_array_equal(a, b)  # identical exit masks
    speedup = times[False] / times[True]
    floor = 1.0 if FAST else 1.2
    assert speedup >= floor, (
        f"batched exit heads {speedup:.2f}x vs sequential (floor {floor}x)"
    )
    return [_pair(
        "heads/probe_step_k5", f"b{batch}_steps{steps}",
        times[True], times[False],
    )]


def sweep_tier_step() -> list[str]:
    """End-to-end TierExecutor decode step, kernels on vs off (K=2,
    bucketed compaction, mixed exits on the fixed seed)."""
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.models import model as M
    from repro.serving import TierExecutor, segments_for_cuts

    cfg = dataclasses.replace(
        get_smoke_config("phi3_mini_3_8b"), num_layers=4, branch_layers=(1, 3)
    )
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = 8
    steps = 2 if FAST else 8
    rows = []
    times = {}
    trajs = {}
    for use_kernels in (True, False):
        ex = TierExecutor(
            cfg, params, segments_for_cuts(cfg, (2,)),
            use_kernels=use_kernels,
        )
        caches = M.init_caches(cfg, batch, 64)
        tok = jax.random.randint(
            jax.random.PRNGKey(2), (batch, 1), 0, cfg.vocab_size
        )
        res, caches = ex.step(tok, 0, caches)  # compile + warm hints
        t0 = time.perf_counter()
        traj = []
        for i in range(steps):
            res, caches = ex.step(res.tokens_dev[:, None], i + 1, caches)
            traj.append(res.tokens)
        times[use_kernels] = (time.perf_counter() - t0) / steps * 1e6
        trajs[use_kernels] = traj
        # The contract the sweep certifies: one sync per step either way.
        assert ex.host_syncs == steps + 1 + ex.overflow_retries
    for a, b in zip(trajs[True], trajs[False]):
        np.testing.assert_array_equal(a, b)  # identical trajectory
    rows.append(_pair(
        "sweep/tier_step_k2", f"b{batch}_steps{steps}",
        times[True], times[False],
    ))
    return rows


def run() -> list[str]:
    rows = [] if FAST else run_reference()
    backend = jax.default_backend()
    mode = "compiled" if backend == "tpu" else "interpret"
    rows.append(f"# kernel-vs-jnp decode sweep: backend={backend}, "
                f"kernel mode={mode} (columns: name,shape,us_kernel,us_jnp)")
    rows += sweep_flash_decode()
    rows += sweep_entropy_exit()
    rows += sweep_ssd_update()
    rows.append("# heads/* rows compare batched vs sequential exit heads "
                "(columns: name,shape,us_batched,us_sequential)")
    rows += sweep_entropy_heads()
    rows += sweep_heads_batched()
    rows += sweep_tier_step()
    return rows


def _bundle(rows: list[str]) -> BenchBundle:
    """Fold the CSV rows into a BENCH_kernels.json bundle.  All metrics
    are wall-clock (interpret-mode kernels off-TPU), so everything lands
    in ``timing``; the backend/mode ride along as cell config."""
    backend = jax.default_backend()
    mode = "compiled" if backend == "tpu" else "interpret"
    config = dict(backend=backend, kernel_mode=mode, fast=FAST)
    b = BenchBundle("kernels")
    for r in rows:
        if r.startswith("#"):
            continue
        parts = r.split(",")
        if len(parts) == 4:  # name,shape,us_kernel,us_jnp
            name, shape, us_k, us_j = parts
            if name.startswith("heads/"):
                # Batched-vs-sequential exit-head cells: the pair is
                # (batched, sequential) and the speedup is the metric the
                # PR gate reads.
                b.cell(f"{name}/{shape}", config=config,
                       timing=dict(us_batched=float(us_k),
                                   us_sequential=float(us_j),
                                   speedup=float(us_j) / float(us_k)))
            else:
                b.cell(f"{name}/{shape}", config=config,
                       timing=dict(us_kernel=float(us_k), us_jnp=float(us_j)))
        elif len(parts) == 3:  # name,us,impl (part-1 reference rows)
            name, us, impl = parts
            b.cell(name, config=dict(**config, impl=impl),
                   timing=dict(us=float(us)))
    return b


if __name__ == "__main__":
    all_rows = run()
    for r in all_rows:
        print(r)
    print(f"\nwrote {_bundle(all_rows).write()}")
