"""Kernel microbenchmarks: wall-clock of the jnp reference paths (what the
CPU host actually executes) + interpret-mode correctness spot checks.

On TPU the Pallas kernels replace the jnp paths; here the jnp oracle IS the
executable implementation, so its timing is what the serving engine sees.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref


def _time(fn, *args, iters=20, warmup=3) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run() -> list[str]:
    rows = []
    key = jax.random.PRNGKey(0)

    # Entropy-exit over a Qwen3-sized vocab (the per-branch confidence test).
    logits = jax.random.normal(key, (64, 151_936), jnp.float32)
    f = jax.jit(lambda x: ref.entropy_exit_ref(x, 0.5))
    rows.append(f"kernel/entropy_exit_b64_v152k,{_time(f, logits):.1f},jnp_ref")

    # Flash-decode against a 32k cache (decode_32k per-layer shape).
    q = jax.random.normal(key, (8, 32, 128), jnp.bfloat16)
    k = jax.random.normal(key, (8, 32_768, 8, 128), jnp.bfloat16)
    v = jax.random.normal(key, (8, 32_768, 8, 128), jnp.bfloat16)
    pos = jnp.arange(32_768, dtype=jnp.int32)
    qpos = jnp.asarray(32_768, jnp.int32)
    f = jax.jit(lambda *a: ref.flash_decode_ref(*a))
    rows.append(
        f"kernel/flash_decode_b8_c32k,{_time(f, q, k, v, pos, qpos):.1f},jnp_ref"
    )

    # SSD scan, mamba2-130m block shape, 4k tokens.
    from repro.models.mamba import ssd_chunked

    x = jax.random.normal(key, (2, 4096, 24, 64), jnp.float32) * 0.5
    a = -jnp.abs(jax.random.normal(key, (2, 4096, 24))) * 0.3
    bm = jax.random.normal(key, (2, 4096, 24, 128)) * 0.5
    cm = jax.random.normal(key, (2, 4096, 24, 128)) * 0.5
    f = jax.jit(lambda *args: ssd_chunked(*args, chunk=64))
    rows.append(f"kernel/ssd_chunked_4k,{_time(f, x, a, bm, cm, iters=5):.1f},jnp_chunked")

    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
