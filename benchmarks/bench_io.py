"""Machine-readable benchmark bundles (``BENCH_<name>.json``).

Every ``make bench-*`` target emits one bundle next to the repo root so
perf claims are diffable across commits: ``tools/bench_check.py`` compares
the current bundle against the last *committed* one (``git show
HEAD:BENCH_<name>.json``) and flags regressions.

Bundle schema (version 1)::

    {
      "schema": 1,
      "bench": "serving",
      "git_sha": "<HEAD at emission>",
      "cells": {
        "<cell>": {
          "config": {...},   # what was run (batch, steps, fast flag, ...)
          "strict": {...},   # deterministic metrics: must match exactly
          "timing": {...}    # wall-clock metrics: ratio-tolerance compare
        }
      }
    }

``strict`` holds structure-derived numbers (host syncs/step, decode-step
counts, analytic FLOPs, solver cuts) that only change when the code
changes; ``timing`` holds noisy wall-clock numbers.  Cells are *merged*
into an existing bundle on write, so a partial run (``REPRO_BENCH_ONLY``)
refreshes only its own cells.  A cell is only comparable when its
``config`` matches the committed one — fast-mode runs never get diffed
against full-mode baselines.
"""

from __future__ import annotations

import json
import os
import subprocess

__all__ = ["BenchBundle", "bundle_path", "git_sha"]

SCHEMA = 1
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def git_sha() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True, text=True, cwd=_REPO_ROOT, timeout=10,
        )
        return out.stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def bundle_path(name: str) -> str:
    return os.path.join(
        os.environ.get("REPRO_BENCH_DIR", _REPO_ROOT), f"BENCH_{name}.json"
    )


class BenchBundle:
    def __init__(self, name: str):
        self.name = name
        self.cells: dict[str, dict] = {}

    def cell(self, name: str, *, config=None, strict=None, timing=None):
        """Record one benchmark cell; values must be JSON-serializable."""
        self.cells[name] = {
            "config": dict(config or {}),
            "strict": dict(strict or {}),
            "timing": dict(timing or {}),
        }

    def write(self, path: str | None = None) -> str:
        path = path or bundle_path(self.name)
        cells = {}
        if os.path.exists(path):  # partial runs refresh only their cells
            try:
                with open(path) as f:
                    cells = json.load(f).get("cells", {})
            except (json.JSONDecodeError, OSError):
                cells = {}
        cells.update(self.cells)
        data = {
            "schema": SCHEMA,
            "bench": self.name,
            "git_sha": git_sha(),
            "cells": dict(sorted(cells.items())),
        }
        with open(path, "w") as f:
            json.dump(data, f, indent=2, sort_keys=False)
            f.write("\n")
        return path
