"""End-to-end driver (deliverable b): serve a small BranchyNet LM with
batched requests across simulated tier splits, re-optimizing the partition
as network conditions change.

This is the paper's deployment story: the cost model + Dijkstra run in the
control plane at admission time and whenever bandwidth drifts; the data
plane executes the currently-installed split.  Beyond the paper, the same
unified runtime executes a K=3 lattice plan (device -> edge -> cloud) with
per-hop byte accounting, and repartitioning hot-swaps the cuts without
re-jitting unchanged tier segments.

Run:  PYTHONPATH=src python examples/serve_partitioned.py
"""

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import LayerCost, Partitioner, build_cost_profile
from repro.core.multitier import TierSpec, solve_multitier
from repro.core.types import NetworkProfile
from repro.models import model as M
from repro.serving import MultiTierServer, PartitionedServer, ServingEngine
from repro.serving.tiers import bytes_per_sequence

BATCH = 16
PROMPT = 24
CONTEXT = 256
DECODE_STEPS = 16

#: The paper's regime: the raw input sample (an image) dwarfs any layer's
#: output, so cuts past the first layers pay off on slow uplinks.  For the
#: LM stand-in we model a vision-style 32 KiB admission payload.
RAW_INPUT_BYTES = 32 * 1024.0

#: Bandwidth schedule the "deployment" experiences (bits/s).
NETWORK_SCHEDULE = [
    ("wifi", 18.8e6),
    ("4g", 5.85e6),
    ("degraded-3g", 0.4e6),
]


def main() -> None:
    key = jax.random.PRNGKey(0)
    cfg = dataclasses.replace(
        get_smoke_config("qwen3_8b"), num_layers=4, branch_layers=(1, 3)
    )
    params = M.init_params(key, cfg)
    n = cfg.num_layers
    print(f"serving {cfg.name} (reduced): {n} layers, branches {cfg.branch_layers}")

    # ---- calibration pass on the unpartitioned engine (K=1 runtime).
    engine = ServingEngine(cfg, params, context_len=CONTEXT)
    prompts = {
        "tokens": jax.random.randint(key, (BATCH, PROMPT), 0, cfg.vocab_size)
    }
    state = engine.start(prompts)
    _, stats = engine.decode(state, steps=8)
    p_k = stats.conditional_probs()
    print(f"calibrated p_k = {np.round(p_k, 3)} "
          f"(fractions {np.round(stats.exit_fractions(), 3)}), "
          f"{engine.host_syncs} host syncs for 8 decode steps")

    # ---- measured per-layer costs (uniform stub; a real deployment uses
    # core.profiler.measure_layer_times on the edge and cloud tiers).
    costs = [LayerCost(f"block{i}", 0, 0, cfg.d_model * 2.0, 1.5e-3)
             for i in range(1, n + 1)]

    # ---- paper system: 2 tiers, repartitioned as bandwidth drifts.  The
    # server is created once; set_split hot-swaps the cut and re-uses the
    # compiled segment functions of any previously-installed split.
    srv = PartitionedServer(cfg, params, 0)
    for net_name, bw in NETWORK_SCHEDULE:
        profile = build_cost_profile(
            costs, cfg.branch_layers, p_k,
            network=NetworkProfile(net_name, bw),
            gamma=25.0, raw_input_bytes=RAW_INPUT_BYTES,
        )
        plan = Partitioner(profile).solve()
        srv.cost_profile = profile
        srv.set_split(plan.split_layer)
        print(f"\n== network {net_name} ({bw / 1e6:.2f} Mbps) -> {plan.describe()}")

        caches = M.init_caches(cfg, BATCH, CONTEXT)
        tok = jnp.zeros((BATCH, 1), jnp.int32)
        shipped = 0
        edge_exits = 0
        t0 = time.perf_counter()
        for i in range(DECODE_STEPS):
            rep, caches = srv.step(tok, PROMPT + i, caches)
            tok = jnp.asarray(rep.tokens[:, None])
            shipped += rep.shipped
            edge_exits += int(rep.exited_on_edge.sum())
        dt = time.perf_counter() - t0
        total = BATCH * DECODE_STEPS
        print(
            f"   decoded {total} token-steps in {dt:.2f}s: "
            f"{edge_exits} exited on edge, {shipped} crossed the cut "
            f"({(1 - shipped / total) * 100:.0f}% transfer saved), "
            f"model-estimated E[T]={0.0 if rep.est_latency_s is None else rep.est_latency_s * 1e3:.2f} ms/sample"
        )

    # ---- beyond the paper: K=3 lattice plan on the same unified runtime.
    tiers = [
        TierSpec("device", 60.0, uplink_bps=18.8e6),  # wifi to the edge box
        TierSpec("edge", 12.0, uplink_bps=1.10e6),  # 3g backhaul to the cloud
        TierSpec("cloud", 1.0),
    ]
    profile = build_cost_profile(
        costs, cfg.branch_layers, p_k, "3g", 25.0, RAW_INPUT_BYTES
    )
    plan3 = solve_multitier(
        profile.t_c, profile.alpha, profile.branch_exit_probs(), tiers
    )
    print(f"\n== K=3 lattice plan: cuts after {plan3.cut_after}, "
          f"tier_of_layer {plan3.tier_of_layer}, "
          f"E[T]={plan3.expected_time_s * 1e3:.2f} ms")

    srv3 = MultiTierServer.from_plan(
        cfg, params, plan3, tiers, cost=(profile.t_c, profile.alpha)
    )
    caches = M.init_caches(cfg, BATCH, CONTEXT)
    tok = jnp.zeros((BATCH, 1), jnp.int32)
    hop_bytes = np.zeros(len(tiers) - 1)
    hop_shipped = np.zeros(len(tiers) - 1, int)
    for i in range(DECODE_STEPS):
        rep3, caches = srv3.step(tok, PROMPT + i, caches)
        tok = jnp.asarray(rep3.tokens[:, None])
        for j in range(len(rep3.bytes_per_hop)):
            hop_bytes[j] += rep3.bytes_per_hop[j]
            hop_shipped[j] += rep3.shipped_per_hop[j]

    # Per-hop byte accounting must match the installed MultiTierPlan: every
    # survivor crossing hop j carries the residual stream of the plan's cut
    # layer (alpha_{c_j}; a cut before layer 1 ships the 4-byte token id).
    for j, cut in enumerate(srv3.cuts[: len(rep3.bytes_per_hop)]):
        per_seq = bytes_per_sequence(cfg, cut)
        assert hop_bytes[j] == hop_shipped[j] * per_seq
        if cut > 0:
            assert per_seq == profile.alpha[cut]
        print(f"   hop {tiers[j].name}->{tiers[j + 1].name} (cut after v_{cut}): "
              f"{hop_shipped[j]} survivors, {hop_bytes[j] / 1024:.1f} KiB "
              f"over {tiers[j].uplink_bps / 1e6:.2f} Mbps "
              f"(matches plan alpha)")
    print(f"   last step est E[T]={rep3.est_latency_s * 1e3:.2f} ms/sample, "
          f"exit tiers {np.bincount(rep3.exit_tier + 1, minlength=len(tiers) + 1)}")
    # Survivor compaction: each downstream tier ran a dense sub-batch
    # padded to the bucket ladder, not the masked full batch.
    for j, hop in enumerate(rep3.compaction):
        print(f"   hop {j}: {hop.survivors} survivors -> bucket {hop.bucket} "
              f"({hop.padded_waste} padding rows), "
              f"{srv3.executor.overflow_retries} overflow retries total")

    # ---- pipelined overlap: the serial runtime pays compute + every hop's
    # transfer per step; overlap="pipelined" overlaps transfers with the
    # next step's compute, so the steady-state step cost is the bottleneck
    # stage max_j(compute_j, transfer_j).  The optimal cut can MOVE under
    # overlap — re-solve with overlap=True before installing.
    plan3o = solve_multitier(
        profile.t_c, profile.alpha, profile.branch_exit_probs(), tiers,
        overlap=True,
    )
    print(f"\n== pipelined K=3: serial plan cuts {plan3.cut_after} "
          f"(E[T] {plan3.expected_time_s * 1e3:.2f} ms) vs overlap plan "
          f"cuts {plan3o.cut_after} "
          f"(E[T]/step {plan3o.expected_time_s * 1e3:.2f} ms)")
    per_seq = bytes_per_sequence(cfg, 2)
    sim_tiers = [  # ~35 ms / ~20 ms per-hop transfers at full batch
        TierSpec("device", 60.0, per_seq * BATCH * 8.0 / 0.035),
        TierSpec("edge", 12.0, per_seq * BATCH * 8.0 / 0.020),
        TierSpec("cloud", 1.0),
    ]
    for overlap in ("serial", "pipelined"):
        srvp = MultiTierServer(
            cfg, params, sim_tiers, (2, 3),
            cost=(profile.t_c, profile.alpha),
            simulate_network=True, overlap=overlap,
        )
        caches = M.init_caches(cfg, BATCH, CONTEXT)
        tok = jnp.zeros((BATCH, 1), jnp.int32)
        repp, caches = srvp.step(tok, PROMPT, caches)  # warm the jit
        tok = jnp.asarray(repp.tokens[:, None])
        srvp.executor.drain()  # don't time the warmup step's transfers
        t0 = time.perf_counter()
        for i in range(1, DECODE_STEPS):
            repp, caches = srvp.step(tok, PROMPT + i, caches)
            tok = jnp.asarray(repp.tokens[:, None])
        srvp.executor.drain()  # account the trailing in-flight transfers
        dt = (time.perf_counter() - t0) / (DECODE_STEPS - 1)
        print(f"   {overlap:<9} {dt * 1e3:7.1f} ms/step "
              f"(sim transfers {tuple(round(s * 1e3) for s in repp.sim_transfer_s)} ms, "
              f"est E[T]/step {repp.est_latency_s * 1e3:.2f} ms)")

    # ---- continuous batching on the K=3 plan: a stream of requests with
    # staggered arrivals, mixed prompt lengths and budgets flows through
    # submit()/drain() — finished/early-exited requests retire mid-flight
    # and waiting prompts prefill into the freed KV rows, so nobody waits
    # for a lock-step wave to drain.
    srvr = MultiTierServer(
        cfg, params, tiers, plan3.cut_after,
        cost=(profile.t_c, profile.alpha),
        slots=6, context_len=CONTEXT,
    )
    rng = np.random.default_rng(0)
    rids = []
    for i in range(10):
        plen = int(rng.choice((8, 16)))
        prompt = rng.integers(0, cfg.vocab_size, size=plen)
        rids.append(srvr.submit(
            prompt, int(rng.integers(3, 10)),
            stop_on_exit=bool(i % 2), arrival_step=i,
        ))
    results = srvr.drain()
    sched = srvr.scheduler
    print(f"\n== continuous batching on the K=3 plan: {len(results)} "
          f"requests over {sched.decode_steps} decode steps "
          f"({sched.executor.host_syncs} host syncs), 6 slots")
    for r in results:
        print(f"   req {r.rid}: slot {r.slot}, admitted step "
              f"{r.admitted_step}, {len(r.tokens)} tokens, "
              f"exits {sum(r.exited)}, TTFT {r.ttft_s * 1e3:.0f} ms, "
              f"latency {r.latency_s * 1e3:.0f} ms")
    # Per-request accounting sanity: every request finished, decoded at
    # least one token within budget, and latency dominates its TTFT.
    assert len(results) == len(rids)
    for rid in rids:
        r = sched.results[rid]
        assert r.done and 1 <= len(r.tokens)
        assert r.ttft_s is not None and 0 < r.ttft_s <= r.latency_s
        assert r.retired_step > r.admitted_step >= 0
    # 10 requests over 6 slots: at least one KV row served two occupants.
    slot_uses = np.bincount([r.slot for r in results], minlength=6)
    assert slot_uses.max() >= 2, "expected a recycled slot"
    print(f"   slot reuse histogram {slot_uses.tolist()} — recycled rows "
          f"served later arrivals with bitwise-solo trajectories "
          f"(tests/test_scheduler.py pins the invariant)")

    # ---- fault plane: mid-run link kill with graceful degradation.  A
    # scripted flap takes the edge->cloud hop down; retries exhaust, the
    # circuit breaker opens, and survivors finalize from the deepest exit
    # head below the broken hop (tokens still emit, flagged degraded).
    # The controller ingests the breaker event and re-solves with the
    # hop's availability at 0 — the new cuts ship nothing across it.
    from repro.serving import (
        FlapWindow, HopPolicy, LinkFaultModel, RepartitionController,
        RequestScheduler,
    )
    fault_tiers = [
        TierSpec("edge", 12.0, uplink_bps=18.8e6),
        TierSpec("mid", 4.0, uplink_bps=5.85e6),
        TierSpec("cloud", 1.0),
    ]
    srvf = MultiTierServer(
        cfg, params, fault_tiers, (1, 3), simulate_network=True,
        slots=6, context_len=CONTEXT,
        fault_model=LinkFaultModel(
            seed=0, flaps=(FlapWindow(hop=1, start_step=6, end_step=10_000),)
        ),
        hop_policy=HopPolicy(timeout_s=0.02, max_retries=1,
                             backoff_s=0.002, breaker_threshold=2),
    )
    ctl = RepartitionController(srvf, profile, tiers=list(fault_tiers))
    schedf = RequestScheduler(srvf, 6, CONTEXT, on_step=[ctl.observe])
    for i in range(10):
        plen = int(rng.choice((8, 16)))
        schedf.submit(rng.integers(0, cfg.vocab_size, size=plen),
                      int(rng.integers(3, 10)), arrival_step=i)
    resultsf = schedf.drain()
    deg = sum(r.degraded_tokens for r in resultsf)
    print(f"\n== fault plane: hop mid->cloud killed at step 6 — "
          f"{len(resultsf)} requests still completed "
          f"({deg}/{schedf.total_tokens} tokens degraded via the fallback "
          f"head, {srvf.executor.fault_retries} retries)")
    print(f"   controller: {ctl.fault_resolves} availability re-solve(s), "
          f"cuts now {srvf.cuts}, hop health {ctl.hop_health()}")
    assert all(r.done for r in resultsf)
    assert ctl.fault_resolves >= 1 and srvf.cuts[1] == cfg.num_layers
    print("   every request completed despite the dead link — "
          "tests/test_faults.py pins the degraded-step contract")


if __name__ == "__main__":
    main()
