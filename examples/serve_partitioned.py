"""End-to-end driver (deliverable b): serve a small BranchyNet LM with
batched requests across a simulated edge/cloud split, re-optimizing the
partition as network conditions change.

This is the paper's deployment story: the cost model + Dijkstra run in the
control plane at admission time and whenever bandwidth drifts; the data
plane executes the currently-installed split.

Run:  PYTHONPATH=src python examples/serve_partitioned.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import LayerCost, Partitioner, build_cost_profile
from repro.models import model as M
from repro.serving.engine import ServingEngine
from repro.serving.partitioned import PartitionedServer

BATCH = 16
PROMPT = 24
CONTEXT = 256
DECODE_STEPS = 16

#: Bandwidth schedule the "deployment" experiences (bits/s).
NETWORK_SCHEDULE = [
    ("wifi", 18.8e6),
    ("4g", 5.85e6),
    ("degraded-3g", 0.4e6),
]


def main() -> None:
    key = jax.random.PRNGKey(0)
    cfg = get_smoke_config("qwen3_8b")
    params = M.init_params(key, cfg)
    n = cfg.num_layers
    print(f"serving {cfg.name} (reduced): {n} layers, branches {cfg.branch_layers}")

    # ---- calibration pass on the unpartitioned engine.
    engine = ServingEngine(cfg, params, context_len=CONTEXT)
    prompts = {
        "tokens": jax.random.randint(key, (BATCH, PROMPT), 0, cfg.vocab_size)
    }
    state = engine.start(prompts)
    _, stats = engine.decode(state, steps=8)
    p_k = stats.conditional_probs()
    print(f"calibrated p_k = {np.round(p_k, 3)} "
          f"(fractions {np.round(stats.exit_fractions(), 3)})")

    # ---- measured per-layer costs (uniform stub; a real deployment uses
    # core.profiler.measure_layer_times on the edge and cloud tiers).
    costs = [LayerCost(f"block{i}", 0, 0, cfg.d_model * 2.0, 1.5e-3)
             for i in range(1, n + 1)]

    for net_name, bw in NETWORK_SCHEDULE:
        profile = build_cost_profile(
            costs, cfg.branch_layers, p_k,
            network=__import__("repro.core.types", fromlist=["NetworkProfile"])
            .NetworkProfile(net_name, bw),
            gamma=25.0, raw_input_bytes=PROMPT * 4.0,
        )
        plan = Partitioner(profile).solve()
        print(f"\n== network {net_name} ({bw / 1e6:.2f} Mbps) -> {plan.describe()}")

        srv = PartitionedServer(cfg, params, plan.split_layer, cost_profile=profile)
        caches = M.init_caches(cfg, BATCH, CONTEXT)
        tok = jnp.zeros((BATCH, 1), jnp.int32)
        shipped = 0
        edge_exits = 0
        t0 = time.perf_counter()
        for i in range(DECODE_STEPS):
            rep, caches = srv.step(tok, PROMPT + i, caches)
            tok = jnp.asarray(rep.tokens[:, None])
            shipped += rep.shipped
            edge_exits += int(rep.exited_on_edge.sum())
        dt = time.perf_counter() - t0
        total = BATCH * DECODE_STEPS
        print(
            f"   decoded {total} token-steps in {dt:.2f}s: "
            f"{edge_exits} exited on edge, {shipped} crossed the cut "
            f"({(1 - shipped / total) * 100:.0f}% transfer saved), "
            f"model-estimated E[T]={0.0 if rep.est_latency_s is None else rep.est_latency_s * 1e3:.2f} ms/sample"
        )


if __name__ == "__main__":
    main()
