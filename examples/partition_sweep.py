"""Reproduce the paper's sensitivity analysis (Figs. 4 and 5) end to end
and print ASCII plots — the whole sweep is one vmapped shortest-path solve.

Run:  PYTHONPATH=src:. python examples/partition_sweep.py
"""

import numpy as np

from benchmarks.fig4_inference_time import GAMMAS, NETWORKS, sweep as sweep4, validate
from benchmarks.fig5_partition_layer import PROBS, sweep as sweep5


def ascii_plot(xs, series: dict, width=64, height=12, xlab="", ylab=""):
    lo = min(float(np.min(v)) for v in series.values())
    hi = max(float(np.max(v)) for v in series.values())
    hi = hi if hi > lo else lo + 1
    grid = [[" "] * width for _ in range(height)]
    marks = "ox+*#@"
    for (name, ys), mark in zip(series.items(), marks):
        for x, y in zip(xs, ys):
            col = int((x - xs[0]) / (xs[-1] - xs[0] + 1e-12) * (width - 1))
            row = height - 1 - int((y - lo) / (hi - lo) * (height - 1))
            grid[row][col] = mark
    print(f"  {ylab} [{lo:.3g} .. {hi:.3g}]")
    for row in grid:
        print("  |" + "".join(row))
    print("  +" + "-" * width + f"> {xlab}")
    for (name, _), mark in zip(series.items(), marks):
        print(f"    {mark} = {name}")


def main() -> None:
    print("=== Fig. 4: E[T_inf] vs exit probability (gamma = 10) ===")
    res = sweep4()
    ps = res[("3g", 10.0)][0]
    ascii_plot(
        ps,
        {net: res[(net, 10.0)][1] for net in NETWORKS},
        xlab="p(exit at branch)",
        ylab="E[T] s",
    )
    rep = validate(res)
    for g in GAMMAS:
        r = rep[f"reduction_pct_gamma{int(g)}"]
        print(f"  gamma={g:6.0f}: time reduction p0->p1: "
              f"3G {r['3g']:.1f}%  4G {r['4g']:.1f}%  WiFi {r['wifi']:.1f}%")
    print("  (paper, gamma=10: 87.27% / 82.98% / 70%)")

    print("\n=== Fig. 5: chosen partition layer vs gamma (3G) ===")
    res5 = sweep5()
    gs = res5[("3g", PROBS[0])][0]
    ascii_plot(
        np.log10(gs),
        {f"p={p}": res5[("3g", p)][1] for p in PROBS},
        xlab="log10 gamma",
        ylab="split layer",
    )


if __name__ == "__main__":
    main()
