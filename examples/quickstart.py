"""Quickstart: the paper's full loop in one script.

1. Build a (reduced) BranchyNet LM — a phi3-family trunk with 1 side branch.
2. Serve a batch and MEASURE per-branch exit statistics (calibration).
3. Profile per-layer costs, build the cost model (Eq. 1-6).
4. Solve the partitioning as a shortest path (Sec. V, Dijkstra).
5. Deploy the plan on the two-tier PartitionedServer and decode.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import (
    Partitioner,
    build_cost_profile,
    LayerCost,
)
from repro.models import model as M
from repro.serving.engine import ServingEngine
from repro.serving.partitioned import PartitionedServer


def main() -> None:
    # --- 1. model -----------------------------------------------------------
    cfg = get_smoke_config("phi3_mini_3_8b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    print(f"model: {cfg.name} (reduced) — {cfg.num_layers} layers, "
          f"branches after {cfg.branch_layers}")

    # --- 2. serve + calibrate -----------------------------------------------
    engine = ServingEngine(cfg, params, context_len=128)
    prompts = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                                            cfg.vocab_size)}
    state = engine.start(prompts)
    tokens, stats = engine.decode(state, steps=12)
    print(f"decoded {tokens.shape} tokens; exit fractions per branch+final: "
          f"{np.round(stats.exit_fractions(), 3)}")
    p_k = stats.conditional_probs()
    print(f"calibrated conditional exit probs p_k = {np.round(p_k, 3)}")

    # --- 3. per-layer cost model ---------------------------------------------
    # For the quickstart we use uniform synthetic layer times; see
    # benchmarks/alexnet_profile.py for measured profiles.
    n = cfg.num_layers
    costs = [
        LayerCost(f"block{i}", 0.0, 0.0, cfg.d_model * 2.0, 2e-3)
        for i in range(1, n + 1)
    ]
    profile = build_cost_profile(
        costs,
        branch_positions=cfg.branch_layers,
        exit_probs=p_k,
        network="4g",
        gamma=50.0,
        raw_input_bytes=16 * 4,  # the token prompt
    )

    # --- 4. optimal split (the paper's contribution) -------------------------
    plan = Partitioner(profile).solve()
    print(plan.describe())
    for net in ("3g", "4g", "wifi"):
        alt = Partitioner(profile).with_network(net).solve()
        print(f"  under {net:4s}: split={alt.split_layer} "
              f"E[T]={alt.expected_time_s * 1e3:.2f} ms")

    # --- 5. partitioned serving ----------------------------------------------
    srv = PartitionedServer(cfg, params, plan.split_layer, cost_profile=profile)
    caches = M.init_caches(cfg, 8, 128)
    # re-prefill through the engine cache path for simplicity
    tok = jnp.asarray(tokens[:, -1:])
    pos = int(state["pos"])
    shipped_total = 0
    for i in range(8):
        rep, caches = srv.step(tok, pos + i, caches)
        tok = jnp.asarray(rep.tokens[:, None])
        shipped_total += rep.shipped
    print(f"partitioned decode: {shipped_total}/64 token-steps crossed the "
          f"cut (the rest exited on the edge)")


if __name__ == "__main__":
    main()
