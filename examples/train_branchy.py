"""Train a small BranchyNet LM for a few hundred steps on the synthetic
pipeline (deliverable b): joint main+branch loss (BranchyNet training),
AdamW + cosine schedule, checkpointing, and a final calibration report
showing the trained branches actually exit.

Run:  PYTHONPATH=src python examples/train_branchy.py [--steps 300]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.data.pipeline import SyntheticLM
from repro.models import model as M
from repro.serving.engine import ServingEngine
from repro.training.checkpoint import restore_checkpoint, save_checkpoint
from repro.training.optimizer import cosine_schedule, make_optimizer
from repro.training.train_loop import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="/tmp/branchy_ckpt.npz")
    args = ap.parse_args()

    cfg = get_smoke_config("olmo_1b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))
    print(f"training {cfg.name} (reduced, {n_params/1e6:.1f}M params), "
          f"branches after {cfg.branch_layers}")

    opt = make_optimizer(
        "adamw", lr=cosine_schedule(3e-3, warmup=20, total=args.steps)
    )
    state = init_train_state(params, opt)
    train_step = jax.jit(make_train_step(cfg, opt))

    data = iter(SyntheticLM(cfg, args.batch, args.seq))
    t0 = time.perf_counter()
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        state, metrics = train_step(state, batch)
        if step % 25 == 0 or step == args.steps - 1:
            bl = {k: float(v) for k, v in metrics.get("branch_losses", {}).items()} \
                if "branch_losses" in metrics else {}
            print(
                f"step {step:4d}  loss {float(metrics['loss']):.4f}  "
                f"main {float(metrics.get('main_loss', metrics['loss'])):.4f}  "
                f"gnorm {float(metrics['grad_norm']):.2f}"
            )
    dt = time.perf_counter() - t0
    print(f"{args.steps} steps in {dt:.1f}s ({args.steps / dt:.1f} steps/s)")

    save_checkpoint(args.ckpt, state["params"], step=args.steps)
    restored = restore_checkpoint(args.ckpt, jax.eval_shape(lambda: state["params"]))
    print(f"checkpoint round-trip OK -> {args.ckpt}")

    # Trained-branch calibration: exits should now actually fire.
    engine = ServingEngine(cfg, restored, context_len=args.seq + 32)
    batch = {k: jnp.asarray(v) for k, v in next(data).items() if k == "tokens"}
    stateS = engine.start({"tokens": batch["tokens"][:, : args.seq // 2]})
    _, stats = engine.decode(stateS, steps=16)
    print(f"post-training exit fractions (branches..., final): "
          f"{np.round(stats.exit_fractions(), 3)}")
    print(f"conditional p_k = {np.round(stats.conditional_probs(), 3)}")


if __name__ == "__main__":
    main()
